//! Design-choice ablations — the sweeps that justify the paper's design
//! decisions (§4.6's empirical 30 % WR threshold, §4.3's double
//! buffering, §4.5's reconfiguration, and the 16×16 grid design point).

use crate::config::{AcceleratorConfig, Scheme, SimOptions};
use crate::nn::zoo;
use crate::sim::{PeModel, ReconfigMode, SweepPlan};

use super::{Figure, ReportCtx};

/// §4.6: sweep the WR steal threshold. The paper picks 30 % empirically;
/// the sweep shows the flat basin around it.
pub fn ablation_wr_threshold(ctx: &ReportCtx) -> Figure {
    let net = zoo::googlenet();
    let mut fig = Figure::new(
        "ablation_wr_threshold",
        "WDU steal-threshold sweep (GoogLeNet, IN+OUT+WR cycles normalized to thr=1.0)",
        &["total_cycles_norm", "bp_cycles_norm"],
    );
    fig.notes = "threshold = minimum remaining-work fraction a victim must have (§4.6)".into();
    // All threshold points as one parallel plan; thr 1.0 (stealing
    // disabled) doubles as the normalization baseline.
    const THRESHOLDS: [f64; 7] = [0.05, 0.1, 0.2, 0.3, 0.5, 0.7, 1.0];
    let mut plan = SweepPlan::new();
    for thr in THRESHOLDS {
        let cfg = AcceleratorConfig { wr_threshold: thr, ..ctx.cfg.clone() };
        plan.push(net.clone(), Scheme::InOutWr, &cfg, &ctx.opts);
    }
    let runs = ctx.sweep.run(&plan, &ctx.model);
    let base = &runs[THRESHOLDS.len() - 1];
    let base_total = base.total_cycles();
    let base_bp = base.phase(crate::nn::Phase::Backward).cycles;
    for (thr, r) in THRESHOLDS.iter().zip(&runs) {
        fig.row(
            &format!("thr={thr:.2}"),
            vec![
                r.total_cycles() / base_total,
                r.phase(crate::nn::Phase::Backward).cycles / base_bp,
            ],
        );
    }
    fig
}

/// §4.3: double buffering on/off, per-output cycle cost across sparsity.
pub fn ablation_double_buffering(ctx: &ReportCtx) -> Figure {
    let mut fig = Figure::new(
        "ablation_double_buffering",
        "Double-buffering impact (cycles per output, CRS=1152)",
        &["with_db", "without_db", "gain"],
    );
    fig.notes = "per-output PE cycles at each input-sparsity level".into();
    let mut with = PeModel::from_config(&ctx.cfg);
    let mut without = PeModel::from_config(&ctx.cfg);
    with.double_buffering = true;
    without.double_buffering = false;
    for s in [0.0, 0.2, 0.4, 0.6, 0.8] {
        let (cw, _) = with.cycles_per_output(1152.0, s);
        let (co, _) = without.cycles_per_output(1152.0, s);
        fig.row(&format!("s={s:.1}"), vec![cw, co, co / cw]);
    }
    fig
}

/// §4.5: reconfiguration mode across the receptive-field spectrum.
pub fn ablation_reconfig_spectrum(ctx: &ReportCtx) -> Figure {
    let mut fig = Figure::new(
        "ablation_reconfig",
        "Adder-tree reconfiguration across receptive-field sizes (dense cycles/output)",
        &["none", "direct", "hierarchical"],
    );
    for crs in [32.0, 64.0, 128.0, 288.0, 576.0, 1024.0, 2304.0] {
        let mut vals = Vec::new();
        for mode in [ReconfigMode::None, ReconfigMode::Direct, ReconfigMode::Hierarchical] {
            let mut pe = PeModel::from_config(&ctx.cfg);
            pe.reconfig = mode;
            vals.push(pe.dense_cycles_per_output(crs));
        }
        fig.row(&format!("crs={crs}"), vals);
    }
    fig
}

/// Design-point scaling: PE grid size vs iteration latency & efficiency.
pub fn ablation_grid_scaling(ctx: &ReportCtx) -> Figure {
    let net = zoo::resnet18();
    let mut fig = Figure::new(
        "ablation_grid",
        "PE-grid scaling (ResNet-18 iteration, IN+OUT+WR)",
        &["cycles", "speedup_vs_8x8", "peak_gflops", "node_power_w"],
    );
    let grids = [8usize, 12, 16, 24, 32];
    let cfgs: Vec<AcceleratorConfig> = grids
        .iter()
        .map(|&g| AcceleratorConfig { tx: g, ty: g, ..ctx.cfg.clone() })
        .collect();
    let mut plan = SweepPlan::new();
    for cfg in &cfgs {
        plan.push(net.clone(), Scheme::InOutWr, cfg, &ctx.opts);
    }
    let runs = ctx.sweep.run(&plan, &ctx.model);
    let mut base = None;
    for ((grid, cfg), r) in grids.iter().zip(&cfgs).zip(&runs) {
        let cycles = r.total_cycles();
        let b = *base.get_or_insert(cycles);
        fig.row(
            &format!("{grid}x{grid}"),
            vec![cycles, b / cycles, cfg.peak_flops() / 1e9, cfg.node_power_w()],
        );
    }
    fig
}

/// Sensitivity of WR gains to the spatial imbalance level (tile CV).
pub fn ablation_tile_cv(ctx: &ReportCtx) -> Figure {
    let net = zoo::vgg16();
    let mut fig = Figure::new(
        "ablation_tile_cv",
        "WR gain vs spatial sparsity imbalance (VGG-16 BP)",
        &["no_wr_cycles", "wr_cycles", "wr_gain"],
    );
    fig.notes = "cv = per-tile density coefficient of variation".into();
    let cvs = [0.0, 0.05, 0.1, 0.2, 0.3];
    let mut plan = SweepPlan::new();
    for &cv in &cvs {
        let opts = SimOptions { tile_sparsity_cv: cv, ..ctx.opts.clone() };
        plan.push(net.clone(), Scheme::InOut, &ctx.cfg, &opts);
        plan.push(net.clone(), Scheme::InOutWr, &ctx.cfg, &opts);
    }
    let runs = ctx.sweep.run(&plan, &ctx.model);
    for (i, cv) in cvs.iter().enumerate() {
        let a = runs[2 * i].phase(crate::nn::Phase::Backward).cycles;
        let b = runs[2 * i + 1].phase(crate::nn::Phase::Backward).cycles;
        fig.row(&format!("cv={cv:.2}"), vec![a, b, a / b]);
    }
    fig
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> ReportCtx {
        ReportCtx::with_batch(2)
    }

    #[test]
    fn wr_threshold_has_flat_basin_near_paper_choice() {
        let f = ablation_wr_threshold(&ctx());
        let at_05 = f.value("thr=0.05", "total_cycles_norm").unwrap();
        let at_30 = f.value("thr=0.30", "total_cycles_norm").unwrap();
        let at_100 = f.value("thr=1.00", "total_cycles_norm").unwrap();
        assert!(at_30 < at_100 * 0.97, "stealing must beat no stealing");
        // Diminishing returns below 30%: the residual gain from stealing
        // ever-smaller remainders is under 10% — with the real transfer
        // overheads §4.6 worries about, 30% is the practical lower bound.
        assert!(at_30 - at_05 < 0.10, "residual gain {:.3}", at_30 - at_05);
        assert!(at_05 <= at_30, "lower thresholds steal at least as much");
    }

    #[test]
    fn double_buffering_gain_grows_with_sparsity_then_saturates() {
        let f = ablation_double_buffering(&ctx());
        let g0 = f.value("s=0.0", "gain").unwrap();
        let g4 = f.value("s=0.4", "gain").unwrap();
        assert!(g0 >= 1.5, "dense db gain {g0}");
        assert!(g4 >= 1.0, "sparse db gain {g4}");
    }

    #[test]
    fn reconfig_matters_most_for_small_crs() {
        let f = ablation_reconfig_spectrum(&ctx());
        let small_gain = f.value("crs=32", "none").unwrap() / f.value("crs=32", "hierarchical").unwrap();
        let large_gain =
            f.value("crs=2304", "none").unwrap() / f.value("crs=2304", "hierarchical").unwrap();
        assert!(small_gain > 8.0, "small-CRS gain {small_gain}");
        assert!(large_gain < 1.5, "large-CRS gain {large_gain}");
    }

    #[test]
    fn grid_scaling_is_sublinear_but_monotone() {
        let f = ablation_grid_scaling(&ctx());
        let s16 = f.value("16x16", "speedup_vs_8x8").unwrap();
        let s32 = f.value("32x32", "speedup_vs_8x8").unwrap();
        assert!(s16 > 1.8, "16x16 speedup {s16}");
        assert!(s32 > s16, "scaling must be monotone");
        assert!(s32 < 16.0, "perfect scaling is implausible");
    }

    #[test]
    fn wr_gain_increases_with_imbalance() {
        let f = ablation_tile_cv(&ctx());
        let low = f.value("cv=0.05", "wr_gain").unwrap();
        let high = f.value("cv=0.30", "wr_gain").unwrap();
        assert!(high > low, "WR gain must grow with imbalance: {low} vs {high}");
    }
}
