//! Table 1 (component specs) and Table 2 (platform comparison).

use crate::baselines::{all_platforms, platform_cost, PlatformCost};
use crate::config::AcceleratorConfig;

use super::{Figure, PlatformBenchmark, ReportCtx};

/// Table 1: component power/area, PE and node totals, derived from the
/// configuration (the paper's synthesis numbers are config constants).
pub fn table1_components(cfg: &AcceleratorConfig) -> Figure {
    let e = &cfg.energy;
    let mut fig = Figure::new(
        "table1",
        "Component specifications (power mW, area mm2)",
        &["power_mW", "area_mm2"],
    );
    fig.notes = format!(
        "node: {}x{} PEs, {} lanes/PE, {} MHz; peak {:.0} GFLOPs/s",
        cfg.tx,
        cfg.ty,
        cfg.lanes,
        cfg.freq_hz / 1e6,
        cfg.peak_flops() / 1e9
    );
    fig.row("neuron/syn regfile", vec![e.regfile_power_w * 1e3, 0.3820]);
    fig.row("nz idx regfile", vec![e.idx_regfile_power_w * 1e3, 0.0602]);
    fig.row("mac units", vec![e.mac_power_w * 1e3, 0.1235]);
    fig.row("reconfig adder tree", vec![e.adder_tree_power_w * 1e3, 0.0803]);
    fig.row("nz encoder", vec![e.encoder_power_w * 1e3, 0.0113]);
    fig.row("control", vec![e.control_power_w * 1e3, 0.0313]);
    fig.row(
        "sram buffers",
        vec![(e.sram_dynamic_w + e.sram_static_w) * 1e3, 0.3696],
    );
    fig.row("PE total", vec![e.pe_total_w * 1e3, 1.0468]);
    fig.row(
        "node total",
        vec![cfg.node_power_w() * 1e3, 1.0468 * cfg.pe_count() as f64],
    );
    fig
}

/// Deterministic provenance suffix for the platform comparison: base
/// batch/seed plus any trace/scenario fingerprints the benchmarks carry
/// (content fingerprints, never filesystem paths — the note must be
/// byte-identical across serve/CLI and `--jobs` levels).
fn platform_notes(ctx: &ReportCtx, benches: &[PlatformBenchmark]) -> String {
    let mut s = format!("batch {}, seed {}", ctx.opts.batch, ctx.opts.seed);
    let mut traces: Vec<u64> =
        benches.iter().filter_map(|b| b.opts.trace_fingerprint).collect();
    traces.sort_unstable();
    traces.dedup();
    for fp in traces {
        s.push_str(&format!(", trace {fp:016x}"));
    }
    let mut scenarios: Vec<u64> =
        benches.iter().filter_map(|b| b.opts.scenario_fingerprint).collect();
    scenarios.sort_unstable();
    scenarios.dedup();
    for fp in scenarios {
        s.push_str(&format!(", scenario {fp:016x}"));
    }
    s
}

/// Per-platform costs for every benchmark, rows × benchmarks.
fn platform_cost_matrix(
    ctx: &ReportCtx,
    benches: &[PlatformBenchmark],
) -> Vec<(crate::baselines::Platform, Vec<PlatformCost>)> {
    all_platforms(&ctx.cfg)
        .into_iter()
        .map(|p| {
            let costs = benches
                .iter()
                .map(|b| platform_cost(&p, &b.net, &ctx.cfg, &b.opts, &b.model, &ctx.sweep))
                .collect();
            (p, costs)
        })
        .collect()
}

/// Table 2: platform comparison — published specs plus, per benchmark,
/// the measured training-iteration latency (ms) and energy (mJ). The
/// benchmark set defaults to {VGG-16, ResNet-18} and is overridden by
/// `--replay` (the trace's network under its measured maps) or
/// `--scenario` (one benchmark per expanded point).
pub fn table2_platforms(ctx: &ReportCtx) -> Figure {
    let benches = ctx.platform_benchmarks();
    let mut columns: Vec<String> =
        ["power_W", "peak_GOps", "eff_GOps_W", "area_mm2"].map(String::from).into();
    for b in &benches {
        columns.push(format!("{}_ms", b.label));
        columns.push(format!("{}_mJ", b.label));
    }
    let cols: Vec<&str> = columns.iter().map(|c| c.as_str()).collect();
    let mut fig = Figure::new(
        "table2",
        "Platform comparison (training iteration latency ms / energy mJ)",
        &cols,
    );
    fig.notes = platform_notes(ctx, &benches);
    for (p, costs) in platform_cost_matrix(ctx, &benches) {
        let mut vals = vec![
            p.power_w,
            p.peak_gops,
            p.energy_eff_gops_w,
            // Unpublished area renders as n/a and serializes as null.
            p.area_mm2.unwrap_or(f64::NAN),
        ];
        for c in &costs {
            vals.push(c.latency_ms);
            vals.push(c.energy_j * 1e3);
        }
        fig.row(p.name, vals);
    }
    fig
}

/// `platforms` figure: every platform's latency and energy as a ratio
/// over This Work, per benchmark — the comparison chart behind Table 2
/// (This Work's row is 1.0 everywhere by construction).
pub fn figure_platforms(ctx: &ReportCtx) -> Figure {
    let benches = ctx.platform_benchmarks();
    let mut columns: Vec<String> = Vec::new();
    for b in &benches {
        columns.push(format!("{}_latency_x", b.label));
        columns.push(format!("{}_energy_x", b.label));
    }
    let cols: Vec<&str> = columns.iter().map(|c| c.as_str()).collect();
    let mut fig = Figure::new(
        "platforms",
        "Platform comparison: latency/energy relative to This Work (x)",
        &cols,
    );
    fig.notes = platform_notes(ctx, &benches);
    let matrix = platform_cost_matrix(ctx, &benches);
    let ours = &matrix.last().expect("platform list is never empty").1;
    for (p, costs) in &matrix {
        let mut vals = Vec::new();
        for (c, o) in costs.iter().zip(ours.iter()) {
            vals.push(c.latency_ms / o.latency_ms);
            vals.push(c.energy_j / o.energy_j);
        }
        fig.row(p.name, vals);
    }
    fig
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper_totals() {
        let f = table1_components(&AcceleratorConfig::default());
        assert!((f.value("PE total", "power_mW").unwrap() - 75.0).abs() < 1e-9);
        let node = f.value("node total", "power_mW").unwrap();
        assert!((node - 19200.0).abs() < 1.0, "node {node} mW");
    }

    #[test]
    fn table2_this_work_wins_among_big_accelerators() {
        let ctx = ReportCtx::with_batch(4);
        let f = table2_platforms(&ctx);
        assert_eq!(f.rows.len(), 11);
        let ours_vgg = f.value("This Work", "vgg16_ms").unwrap();
        let ddn_vgg = f.value("DaDianNao", "vgg16_ms").unwrap();
        let cnv_vgg = f.value("CNVLUTIN", "vgg16_ms").unwrap();
        let cpu_vgg = f.value("Dual Xeon E5 2560 v3", "vgg16_ms").unwrap();
        assert!(ours_vgg < ddn_vgg && ddn_vgg > cnv_vgg && cnv_vgg > ours_vgg);
        assert!(cpu_vgg / ours_vgg > 10.0, "order of magnitude vs CPU");
        // The measured-sparsity rows are present with live latencies.
        for name in ["SparseNN", "SparseTrain", "TensorDash"] {
            let ms = f.value(name, "vgg16_ms").unwrap();
            assert!(ms.is_finite() && ms > 0.0, "{name}: {ms}");
        }
    }

    #[test]
    fn table2_has_area_and_energy_columns() {
        let ctx = ReportCtx::with_batch(2);
        let f = table2_platforms(&ctx);
        // CPU publishes no die area — explicit n/a, not a number.
        assert!(f.value("Dual Xeon E5 2560 v3", "area_mm2").unwrap().is_nan());
        assert!(f.value("This Work", "area_mm2").unwrap() > 0.0);
        // Measured energy per iteration, in mJ, for every benchmark.
        for col in ["vgg16_mJ", "resnet18_mJ"] {
            let ours = f.value("This Work", col).unwrap();
            let gpu = f.value("NVidia GTX 1080 Ti", col).unwrap();
            assert!(ours > 0.0 && gpu > 0.0);
            assert!(gpu > ours, "GPU burns more energy per iteration ({col})");
        }
        // The serialized table must stay valid JSON despite the n/a cell.
        assert!(crate::util::json::Json::parse(&f.to_json().dump()).is_ok());
    }

    #[test]
    fn platforms_figure_normalizes_to_this_work() {
        let ctx = ReportCtx::with_batch(2);
        let f = figure_platforms(&ctx);
        assert_eq!(f.rows.len(), 11);
        for col in ["vgg16_latency_x", "vgg16_energy_x", "resnet18_latency_x"] {
            assert!((f.value("This Work", col).unwrap() - 1.0).abs() < 1e-12);
        }
        // Simulator-consuming accelerator rows sit above 1.0 on latency.
        for name in ["DaDianNao", "CNVLUTIN", "SparseNN", "SparseTrain", "TensorDash"] {
            let x = f.value(name, "resnet18_latency_x").unwrap();
            assert!(x > 1.0, "{name}: {x}");
        }
    }

    #[test]
    fn table2_responds_to_benchmark_override() {
        use crate::nn::zoo;
        let ctx = ReportCtx::with_batch(2);
        let mut ctx2 = ReportCtx::with_batch(2);
        ctx2.benchmarks = Some(vec![PlatformBenchmark {
            label: "agos_cnn@test".to_string(),
            net: zoo::agos_cnn(),
            opts: ctx2.opts.clone(),
            model: ctx2.model.clone(),
        }]);
        let default = table2_platforms(&ctx);
        let overridden = table2_platforms(&ctx2);
        assert!(default.col("vgg16_ms").is_some());
        assert!(overridden.col("vgg16_ms").is_none());
        let ms = overridden.value("This Work", "agos_cnn@test_ms").unwrap();
        assert!(ms.is_finite() && ms > 0.0);
    }
}
