//! Table 1 (component specs) and Table 2 (platform comparison).

use crate::baselines::{all_platforms, iteration_latency_ms};
use crate::config::AcceleratorConfig;
use crate::nn::zoo;

use super::{Figure, ReportCtx};

/// Table 1: component power/area, PE and node totals, derived from the
/// configuration (the paper's synthesis numbers are config constants).
pub fn table1_components(cfg: &AcceleratorConfig) -> Figure {
    let e = &cfg.energy;
    let mut fig = Figure::new(
        "table1",
        "Component specifications (power mW, area mm2)",
        &["power_mW", "area_mm2"],
    );
    fig.notes = format!(
        "node: {}x{} PEs, {} lanes/PE, {} MHz; peak {:.0} GFLOPs/s",
        cfg.tx,
        cfg.ty,
        cfg.lanes,
        cfg.freq_hz / 1e6,
        cfg.peak_flops() / 1e9
    );
    fig.row("neuron/syn regfile", vec![e.regfile_power_w * 1e3, 0.3820]);
    fig.row("nz idx regfile", vec![e.idx_regfile_power_w * 1e3, 0.0602]);
    fig.row("mac units", vec![e.mac_power_w * 1e3, 0.1235]);
    fig.row("reconfig adder tree", vec![e.adder_tree_power_w * 1e3, 0.0803]);
    fig.row("nz encoder", vec![e.encoder_power_w * 1e3, 0.0113]);
    fig.row("control", vec![e.control_power_w * 1e3, 0.0313]);
    fig.row(
        "sram buffers",
        vec![(e.sram_dynamic_w + e.sram_static_w) * 1e3, 0.3696],
    );
    fig.row("PE total", vec![e.pe_total_w * 1e3, 1.0468]);
    fig.row(
        "node total",
        vec![cfg.node_power_w() * 1e3, 1.0468 * cfg.pe_count() as f64],
    );
    fig
}

/// Table 2: platform comparison with per-iteration latency for VGG-16 and
/// ResNet-18 at the evaluation batch size.
pub fn table2_platforms(ctx: &ReportCtx) -> Figure {
    let mut fig = Figure::new(
        "table2",
        "Platform comparison (training iteration latency, ms)",
        &["power_W", "peak_GOps", "eff_GOps_W", "vgg16_ms", "resnet18_ms"],
    );
    fig.notes = format!("batch {}, seed {}", ctx.opts.batch, ctx.opts.seed);
    let vgg = zoo::vgg16();
    let resnet = zoo::resnet18();
    for p in all_platforms() {
        let vgg_ms = iteration_latency_ms(&p, &vgg, &ctx.cfg, &ctx.opts, &ctx.model, &ctx.sweep);
        let res_ms =
            iteration_latency_ms(&p, &resnet, &ctx.cfg, &ctx.opts, &ctx.model, &ctx.sweep);
        fig.row(
            p.name,
            vec![p.power_w, p.peak_gops, p.energy_eff_gops_w, vgg_ms, res_ms],
        );
    }
    fig
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper_totals() {
        let f = table1_components(&AcceleratorConfig::default());
        assert!((f.value("PE total", "power_mW").unwrap() - 75.0).abs() < 1e-9);
        let node = f.value("node total", "power_mW").unwrap();
        assert!((node - 19200.0).abs() < 1.0, "node {node} mW");
    }

    #[test]
    fn table2_this_work_wins_among_big_accelerators() {
        let ctx = ReportCtx::with_batch(4);
        let f = table2_platforms(&ctx);
        assert_eq!(f.rows.len(), 8);
        let ours_vgg = f.value("This Work", "vgg16_ms").unwrap();
        let ddn_vgg = f.value("DaDianNao", "vgg16_ms").unwrap();
        let cnv_vgg = f.value("CNVLUTIN", "vgg16_ms").unwrap();
        let cpu_vgg = f.value("Dual Xeon E5 2560 v3", "vgg16_ms").unwrap();
        assert!(ours_vgg < ddn_vgg && ddn_vgg > cnv_vgg && cnv_vgg > ours_vgg);
        assert!(cpu_vgg / ours_vgg > 10.0, "order of magnitude vs CPU");
    }
}
