//! One generator per paper figure (see DESIGN.md §4 for the mapping).

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::config::{ExecBackend, GatherMode, Scheme, SimOptions};
use crate::nn::{zoo, Network, Phase};
use crate::sim::{NetworkSimResult, PeModel, ReconfigMode, SweepPlan};
use crate::sparsity::gradient_sparsity;

use super::{Figure, ReportCtx};

/// All four schemes over one network — one parallel, cached sweep
/// through the context's shared executor.
fn sweep(net: &Network, ctx: &ReportCtx) -> BTreeMap<&'static str, Arc<NetworkSimResult>> {
    let plan = SweepPlan::grid(std::slice::from_ref(net), &Scheme::ALL, &ctx.cfg, &ctx.opts);
    let runs = ctx.sweep.run(&plan, &ctx.model);
    Scheme::ALL.iter().zip(runs).map(|(s, r)| (s.label(), r)).collect()
}

/// Layer-wise BP speedup bars (the Fig 11/12/13 shape): one row per conv
/// layer in `layers`, columns IN / IN+OUT / IN+OUT+WR vs the DC baseline.
fn layerwise_bp_speedup(
    id: &str,
    title: &str,
    net: &Network,
    layers: &[&str],
    ctx: &ReportCtx,
) -> Figure {
    let runs = sweep(net, ctx);
    let mut fig = Figure::new(id, title, &["IN", "IN+OUT", "IN+OUT+WR"]);
    fig.notes = format!(
        "BP speedup over dense baseline, batch {}; seed {}",
        ctx.opts.batch, ctx.opts.seed
    );
    for name in layers {
        let dc = runs["DC"]
            .layer(name, Phase::Backward)
            .unwrap_or_else(|| panic!("layer '{name}' has no BP entry"))
            .cycles;
        let vals = ["IN", "IN+OUT", "IN+OUT+WR"]
            .iter()
            .map(|s| dc / runs[*s].layer(name, Phase::Backward).unwrap().cycles)
            .collect();
        fig.row(name, vals);
    }
    fig
}

/// Fig 3b: feature & gradient sparsity across the inception-3b block.
pub fn fig3b_inception_sparsity(ctx: &ReportCtx) -> Figure {
    let net = zoo::googlenet();
    let fwd = ctx.model.assign(&net);
    let gs = gradient_sparsity(&net, &fwd);
    let mut fig = Figure::new(
        "fig3b",
        "Inception-3b feature/gradient sparsity",
        &["feature", "gradient"],
    );
    fig.notes = "fraction of zeros at each layer output (FP feature map, BP gradient)".into();
    for l in net.layers() {
        if !l.name.starts_with("inception_3b") {
            continue;
        }
        // Report ReLU and pool outputs (where sparsity lives), like Fig 3b.
        if l.kind.is_relu() || matches!(l.kind, crate::nn::LayerKind::MaxPool { .. }) {
            fig.row(&l.name, vec![fwd[l.id], gs[l.id]]);
        }
    }
    fig
}

/// Fig 3d: min / avg / max sparsity across a batch of 16, per network.
pub fn fig3d_batch_sparsity(ctx: &ReportCtx) -> Figure {
    let mut fig = Figure::new("fig3d", "Batch sparsity min/avg/max", &["min", "avg", "max"]);
    fig.notes = format!("across batch of {} images, ReLU outputs only", ctx.opts.batch);
    for net in zoo::all_networks() {
        let batch = ctx.model.assign_batch(&net, ctx.opts.batch);
        let mut per_image: Vec<f64> = Vec::new();
        for img in &batch {
            let relus: Vec<f64> = net
                .layers()
                .iter()
                .filter(|l| l.kind.is_relu())
                .map(|l| img[l.id])
                .collect();
            per_image.push(relus.iter().sum::<f64>() / relus.len() as f64);
        }
        let min = per_image.iter().cloned().fold(f64::MAX, f64::min);
        let max = per_image.iter().cloned().fold(f64::MIN, f64::max);
        let avg = per_image.iter().sum::<f64>() / per_image.len() as f64;
        fig.row(&net.name, vec![min, avg, max]);
    }
    fig
}

/// Fig 11a: VGG-16 layer-wise BP speedups.
pub fn fig11a_vgg(ctx: &ReportCtx) -> Figure {
    let net = zoo::vgg16();
    let layers: Vec<String> = net
        .compute_layers()
        .iter()
        .filter(|l| l.name.starts_with("conv") && l.name != "conv1_1")
        .map(|l| l.name.clone())
        .collect();
    let refs: Vec<&str> = layers.iter().map(|s| s.as_str()).collect();
    layerwise_bp_speedup("fig11a", "VGG-16 layer-wise BP speedup", &net, &refs, ctx)
}

/// Fig 11b: GoogLeNet inception-3b layer-wise BP speedups.
pub fn fig11b_googlenet(ctx: &ReportCtx) -> Figure {
    let net = zoo::googlenet();
    let layers: Vec<String> = net
        .compute_layers()
        .iter()
        .filter(|l| l.name.starts_with("inception_3b"))
        .map(|l| l.name.clone())
        .collect();
    let refs: Vec<&str> = layers.iter().map(|s| s.as_str()).collect();
    layerwise_bp_speedup("fig11b", "Inception-3b layer-wise BP speedup", &net, &refs, ctx)
}

/// Fig 12a: DenseNet dense-block-1 layer-wise BP speedups.
pub fn fig12a_densenet(ctx: &ReportCtx) -> Figure {
    let net = zoo::densenet121();
    let layers: Vec<String> = net
        .compute_layers()
        .iter()
        .filter(|l| l.name.starts_with("dense1_"))
        .map(|l| l.name.clone())
        .collect();
    let refs: Vec<&str> = layers.iter().map(|s| s.as_str()).collect();
    layerwise_bp_speedup("fig12a", "DenseNet block-1 layer-wise BP speedup", &net, &refs, ctx)
}

/// Fig 12b: MobileNet pointwise-conv layer-wise BP speedups.
pub fn fig12b_mobilenet(ctx: &ReportCtx) -> Figure {
    let net = zoo::mobilenet_v1();
    let layers: Vec<String> = net
        .compute_layers()
        .iter()
        .filter(|l| l.name.starts_with("pw"))
        .map(|l| l.name.clone())
        .collect();
    let refs: Vec<&str> = layers.iter().map(|s| s.as_str()).collect();
    layerwise_bp_speedup("fig12b", "MobileNet pw-conv layer-wise BP speedup", &net, &refs, ctx)
}

/// Fig 13: ResNet-18 residual-block-2 layer-wise BP speedups.
pub fn fig13_resnet(ctx: &ReportCtx) -> Figure {
    let net = zoo::resnet18();
    let layers = [
        "layer2_0_conv1",
        "layer2_0_conv2",
        "layer2_1_conv1",
        "layer2_1_conv2",
    ];
    layerwise_bp_speedup("fig13", "ResNet-18 block-2 layer-wise BP speedup", &net, &layers, ctx)
}

/// Fig 15: normalized end-to-end execution time with FP/BP/WG breakdown.
pub fn fig15_overall(ctx: &ReportCtx) -> Figure {
    let mut fig = Figure::new(
        "fig15",
        "Normalized CNN execution time (FP+BP+WG)",
        &["DC", "IN", "IN+OUT", "IN+OUT+WR", "speedup", "FP_frac", "BP_frac", "WG_frac"],
    );
    fig.notes =
        "execution time normalized to DC; *_frac is the phase breakdown of IN+OUT+WR".into();
    for net in zoo::all_networks() {
        let runs = sweep(&net, ctx);
        let dc = runs["DC"].total_cycles();
        let best = runs["IN+OUT+WR"].total_cycles();
        let fp = runs["IN+OUT+WR"].phase(Phase::Forward).cycles;
        let bp = runs["IN+OUT+WR"].phase(Phase::Backward).cycles;
        let wg = runs["IN+OUT+WR"].phase(Phase::WeightGrad).cycles;
        fig.row(
            &net.name,
            vec![
                1.0,
                runs["IN"].total_cycles() / dc,
                runs["IN+OUT"].total_cycles() / dc,
                best / dc,
                dc / best,
                fp / best,
                bp / best,
                wg / best,
            ],
        );
    }
    fig
}

/// Fig 16: impact of adder-tree reconfiguration on small receptive fields.
pub fn fig16_reconfig(ctx: &ReportCtx) -> Figure {
    let pe_base = PeModel::from_config(&ctx.cfg);
    let mut fig = Figure::new(
        "fig16",
        "Lane-reconfiguration impact (per-output speedup vs no reconfig)",
        &["none", "direct", "hierarchical"],
    );
    fig.notes = "DenseNet receptive fields: 1x1x64 -> CRS 64, 3x3x64 -> CRS 576".into();
    for (label, crs) in [("1x1x64", 64.0), ("3x3x64", 576.0)] {
        let mut vals = Vec::new();
        let base = {
            let mut pe = pe_base.clone();
            pe.reconfig = ReconfigMode::None;
            pe.dense_cycles_per_output(crs)
        };
        for mode in [ReconfigMode::None, ReconfigMode::Direct, ReconfigMode::Hierarchical] {
            let mut pe = pe_base.clone();
            pe.reconfig = mode;
            vals.push(base / pe.dense_cycles_per_output(crs));
        }
        fig.row(label, vals);
    }
    fig
}

/// Backend validation (figval): analytic vs exact-sampled vs replayed
/// total cycles per scheme on the traced CNN — the engine-level closure
/// of the per-output `analytic_model_tracks_exact_simulation` check. The
/// replay columns synthesize a v2 bitmap capture at the context model's
/// densities (`sparsity::capture_synthetic_trace`) and replay it twice:
/// through the geometry-exact strided receptive-field gather (the
/// production mode — true operand identity, replayed WG pairs) and
/// through the legacy streaming-slice window it replaced, so the
/// geometry upgrade's fidelity is visible per scheme next to the
/// analytic expectation. All columns pin their backend/gather
/// explicitly, so this figure is meaningful under any `--backend`.
pub fn figval_backend(ctx: &ReportCtx) -> Figure {
    let net = zoo::agos_cnn();
    let analytic = SimOptions { backend: ExecBackend::Analytic, ..ctx.opts.clone() };
    let exact = SimOptions { backend: ExecBackend::Exact, ..ctx.opts.clone() };
    let steps = ctx.opts.batch.clamp(1, 4);
    let trace = crate::sparsity::capture_synthetic_trace(
        &net,
        &ctx.model,
        steps,
        ctx.opts.pattern,
        ctx.opts.blob_radius,
    );
    let bank = Arc::new(
        crate::sim::ReplayBank::from_trace(&net, &trace)
            .expect("synthesized traces always carry payloads"),
    );
    let replay_geo = SimOptions {
        backend: ExecBackend::Exact,
        gather: GatherMode::Geometry,
        trace_fingerprint: Some(trace.fingerprint()),
        replay: Some(bank.clone()),
        ..ctx.opts.clone()
    };
    let replay_stream = SimOptions { gather: GatherMode::Streaming, ..replay_geo.clone() };
    let mut fig = Figure::new(
        "figval",
        "Analytic vs exact backend, sampled and replayed (total cycles)",
        &[
            "analytic",
            "exact-sampled",
            "replay-geometry",
            "replay-streaming",
            "geometry/analytic",
            "streaming/analytic",
        ],
    );
    fig.notes = format!(
        "agos_cnn, batch {}, seed {}, exact cap {} outputs/tile, {} sampling, \
         replaying a {steps}-step synthesized capture through the geometry-exact \
         gather and the legacy streaming slice; rows are schemes",
        ctx.opts.batch,
        ctx.opts.seed,
        ctx.opts.exact_outputs_per_tile,
        ctx.opts.pattern.label(),
    );
    for scheme in Scheme::ALL {
        let a = ctx.sweep.one(&net, &ctx.cfg, &analytic, &ctx.model, scheme);
        let e = ctx.sweep.one(&net, &ctx.cfg, &exact, &ctx.model, scheme);
        let g = ctx.sweep.one(&net, &ctx.cfg, &replay_geo, &ctx.model, scheme);
        let s = ctx.sweep.one(&net, &ctx.cfg, &replay_stream, &ctx.model, scheme);
        fig.row(
            scheme.label(),
            vec![
                a.total_cycles(),
                e.total_cycles(),
                g.total_cycles(),
                s.total_cycles(),
                g.total_cycles() / a.total_cycles(),
                s.total_cycles() / a.total_cycles(),
            ],
        );
    }
    fig
}

/// Fig 17: inception-4d tile-latency min/avg/max under each scheme.
pub fn fig17_node(ctx: &ReportCtx) -> Figure {
    let net = zoo::googlenet();
    let mut fig = Figure::new(
        "fig17",
        "Inception-4d tile latency (normalized to DC max)",
        &["min", "avg", "max", "avg/max"],
    );
    fig.notes = "sum over the module's conv layers, FP+BP; rows are schemes".into();
    let runs = sweep(&net, ctx);
    let mut norm = None;
    for scheme in Scheme::ALL {
        let r = &runs[scheme.label()];
        let mut min = 0.0;
        let mut mean = 0.0;
        let mut max = 0.0;
        let mut n = 0usize;
        for l in &r.per_layer {
            if !l.name.starts_with("inception_4d") || l.phase == Phase::WeightGrad {
                continue;
            }
            min += l.tile_min;
            mean += l.tile_mean;
            max += l.tile_max;
            n += 1;
        }
        assert!(n > 0, "no inception_4d layers found");
        let norm_v = *norm.get_or_insert(max);
        fig.row(
            scheme.label(),
            vec![min / norm_v, mean / norm_v, max / norm_v, mean / max],
        );
    }
    fig
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> ReportCtx {
        ReportCtx::with_batch(2)
    }

    #[test]
    fn fig3b_reports_sparsity_in_band() {
        let f = fig3b_inception_sparsity(&ctx());
        assert!(f.rows.len() >= 5);
        for (label, vals) in &f.rows {
            assert!((0.0..=1.0).contains(&vals[0]), "{label}: {}", vals[0]);
            assert!((0.0..=1.0).contains(&vals[1]), "{label}");
        }
        // ReLU rows: paper band 25–55%
        let relu_rows: Vec<_> =
            f.rows.iter().filter(|(l, _)| l.contains("relu")).collect();
        assert!(!relu_rows.is_empty());
        for (l, vals) in relu_rows {
            assert!((0.2..0.65).contains(&vals[0]), "{l}: {}", vals[0]);
        }
    }

    #[test]
    fn fig3d_min_le_avg_le_max() {
        let f = fig3d_batch_sparsity(&ctx());
        assert_eq!(f.rows.len(), 5);
        for (l, v) in &f.rows {
            assert!(v[0] <= v[1] && v[1] <= v[2], "{l}: {v:?}");
            assert!((0.2..0.8).contains(&v[1]), "{l} avg {}", v[1]);
        }
    }

    #[test]
    fn fig11a_speedups_shaped_like_paper() {
        let f = fig11a_vgg(&ctx());
        assert_eq!(f.rows.len(), 12); // 12 convs (conv1_1 has no BP)
        for (l, v) in &f.rows {
            let (inp, both, wr) = (v[0], v[1], v[2]);
            assert!(inp >= 0.95, "{l}: IN {inp}");
            // 5% slack: schemes draw different per-tile jitter sequences
            assert!(both >= inp * 0.95, "{l}: IN+OUT {both} < IN {inp}");
            assert!(wr >= both * 0.95, "{l}: WR {wr} < IN+OUT {both}");
            assert!(wr < 9.0, "{l}: implausible speedup {wr}");
        }
        // post-pool conv rows lose OUT: conv2_1 follows pool1
        let pool_row = f.value("conv2_1", "IN+OUT").unwrap();
        let pool_in = f.value("conv2_1", "IN").unwrap();
        assert!((pool_row / pool_in - 1.0).abs() < 0.06, "post-pool conv gained OUT");
        // inner convs DO gain from OUT
        let inner_gain =
            f.value("conv3_2", "IN+OUT").unwrap() / f.value("conv3_2", "IN").unwrap();
        assert!(inner_gain > 1.3, "inner conv OUT gain {inner_gain}");
    }

    #[test]
    fn fig13_resnet_out_only() {
        let f = fig13_resnet(&ctx());
        for (l, v) in &f.rows {
            // BN blocks input sparsity: IN ≈ 1.0
            assert!((0.9..1.1).contains(&v[0]), "{l}: IN {} should be ~1", v[0]);
            // output sparsity gives the gain (paper: 16–73%)
            assert!(v[2] > 1.05, "{l}: total {} should gain", v[2]);
        }
    }

    #[test]
    fn fig16_matches_paper_ratio() {
        let f = fig16_reconfig(&ctx());
        let hier = f.value("3x3x64", "hierarchical").unwrap();
        let direct = f.value("3x3x64", "direct").unwrap();
        assert!((1.5..2.0).contains(&(hier / direct)), "{}", hier / direct);
        // 1x1x64 is already fine with direct
        let d1 = f.value("1x1x64", "direct").unwrap();
        let h1 = f.value("1x1x64", "hierarchical").unwrap();
        assert!((h1 / d1) < 1.1);
    }

    #[test]
    fn fig17_wr_improves_avg_over_max() {
        let f = fig17_node(&ctx());
        let no_wr = f.value("IN+OUT", "avg/max").unwrap();
        let wr = f.value("IN+OUT+WR", "avg/max").unwrap();
        assert!(wr > no_wr, "WR {wr:.3} !> no-WR {no_wr:.3}");
        assert!(wr > 0.75, "WR utilization {wr:.3} (paper ~0.83)");
    }

    #[test]
    fn figval_backends_agree_and_geometry_is_no_worse_than_streaming() {
        let mut ctx = ReportCtx::with_batch(1);
        ctx.opts.exact_outputs_per_tile = 16; // keep the debug-mode walk fast
        let f = figval_backend(&ctx);
        assert_eq!(f.rows.len(), 4);
        let mut geo_err_sum = 0.0;
        let mut stream_err_sum = 0.0;
        for (label, v) in &f.rows {
            let sampled = v[1] / v[0];
            assert!(
                (0.65..1.55).contains(&sampled),
                "{label}: sampled/analytic ratio {sampled:.3} out of band"
            );
            // Both replay assemblies at matched density must stay in a
            // band around the analytic expectation.
            let (geo, stream) = (v[4], v[5]);
            assert!(
                (0.55..1.7).contains(&geo),
                "{label}: geometry-replay/analytic ratio {geo:.3} out of band"
            );
            assert!(
                (0.55..1.7).contains(&stream),
                "{label}: streaming-replay/analytic ratio {stream:.3} out of band"
            );
            geo_err_sum += (geo - 1.0).abs();
            stream_err_sum += (stream - 1.0).abs();
        }
        // The acceptance bar for the gather upgrade: averaged over the
        // schemes, the geometry-exact series sits at least as close to
        // the analytic expectation as the streaming slice it replaced
        // (small slack for the finite per-tile sample).
        assert!(
            geo_err_sum <= stream_err_sum + 0.20,
            "geometry replay drifted: sum|geo-1| = {geo_err_sum:.3} \
             vs sum|stream-1| = {stream_err_sum:.3}"
        );
    }

    #[test]
    fn figure_generators_share_the_sweep_cache() {
        // fig11b and fig17 both need GoogLeNet under all four schemes;
        // through the shared context the second generator must not
        // simulate anything new.
        let ctx = ctx();
        let misses0 = ctx.sweep.cache().misses();
        let _ = fig11b_googlenet(&ctx);
        let after_first = ctx.sweep.cache().misses();
        assert!(after_first > misses0, "first figure must simulate");
        let _ = fig17_node(&ctx);
        assert_eq!(
            ctx.sweep.cache().misses(),
            after_first,
            "fig17 must be served from fig11b's sweep"
        );
        assert!(ctx.sweep.cache().hits() >= 4);
    }

    #[test]
    fn fig15_totals_normalized() {
        let f = fig15_overall(&ctx());
        assert_eq!(f.rows.len(), 5);
        for (l, v) in &f.rows {
            assert_eq!(v[0], 1.0);
            assert!(v[3] <= v[2] && v[2] <= v[1] && v[1] <= 1.0, "{l}: {v:?}");
            let speedup = v[4];
            assert!((1.2..3.2).contains(&speedup), "{l}: overall {speedup}");
            // breakdown sums to 1
            let s = v[5] + v[6] + v[7];
            assert!((s - 1.0).abs() < 1e-9, "{l}: breakdown {s}");
        }
    }
}
