//! The tabular result container every generator produces.

use crate::util::json::Json;

/// A figure/table: labeled rows of numeric columns.
#[derive(Clone, Debug)]
pub struct Figure {
    /// Paper artifact id, e.g. "fig11a".
    pub id: String,
    pub title: String,
    /// Column headers (not counting the row label).
    pub columns: Vec<String>,
    /// (row label, one value per column).
    pub rows: Vec<(String, Vec<f64>)>,
    /// Free-form provenance notes (series definition, units).
    pub notes: String,
}

impl Figure {
    pub fn new(id: &str, title: &str, columns: &[&str]) -> Figure {
        Figure {
            id: id.to_string(),
            title: title.to_string(),
            columns: columns.iter().map(|c| c.to_string()).collect(),
            rows: Vec::new(),
            notes: String::new(),
        }
    }

    pub fn row(&mut self, label: &str, values: Vec<f64>) {
        assert_eq!(values.len(), self.columns.len(), "row '{label}' arity");
        self.rows.push((label.to_string(), values));
    }

    /// Aligned text rendering for the terminal.
    pub fn render(&self) -> String {
        let mut s = format!("== {} — {} ==\n", self.id, self.title);
        let label_w = self
            .rows
            .iter()
            .map(|(l, _)| l.len())
            .chain(std::iter::once(5))
            .max()
            .unwrap()
            .max(5);
        s.push_str(&format!("{:<label_w$}", "layer"));
        for c in &self.columns {
            s.push_str(&format!(" {c:>12}"));
        }
        s.push('\n');
        for (label, values) in &self.rows {
            s.push_str(&format!("{label:<label_w$}"));
            for v in values {
                if !v.is_finite() {
                    // Missing values (e.g. an unpublished spec) render as
                    // an explicit placeholder, never as NaN/inf text.
                    s.push_str(&format!(" {:>12}", "n/a"));
                } else if v.abs() >= 1000.0 {
                    s.push_str(&format!(" {v:>12.1}"));
                } else {
                    s.push_str(&format!(" {v:>12.3}"));
                }
            }
            s.push('\n');
        }
        if !self.notes.is_empty() {
            s.push_str(&format!("note: {}\n", self.notes));
        }
        s
    }

    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|(l, vs)| {
                Json::from_pairs(vec![
                    ("label", l.as_str().into()),
                    (
                        "values",
                        // Non-finite values would dump as bare `NaN`/`inf`
                        // tokens — invalid JSON — so they serialize as null.
                        Json::Arr(
                            vs.iter()
                                .map(|v| if v.is_finite() { Json::Num(*v) } else { Json::Null })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect();
        Json::from_pairs(vec![
            ("id", self.id.as_str().into()),
            ("title", self.title.as_str().into()),
            ("columns", Json::Arr(self.columns.iter().map(|c| Json::Str(c.clone())).collect())),
            ("rows", Json::Arr(rows)),
            ("notes", self.notes.as_str().into()),
        ])
    }

    /// Write `results/<id>.json`.
    pub fn save(&self, dir: &std::path::Path) -> anyhow::Result<()> {
        self.to_json().write_file(&dir.join(format!("{}.json", self.id)))
    }

    /// Column index by header name.
    pub fn col(&self, name: &str) -> Option<usize> {
        self.columns.iter().position(|c| c == name)
    }

    /// Value lookup by row label + column name.
    pub fn value(&self, row: &str, col: &str) -> Option<f64> {
        let ci = self.col(col)?;
        self.rows.iter().find(|(l, _)| l == row).map(|(_, vs)| vs[ci])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_render_lookup() {
        let mut f = Figure::new("figX", "test", &["IN", "IN+OUT"]);
        f.row("conv1", vec![1.5, 2.5]);
        f.row("conv2", vec![1.2, 3.0]);
        let r = f.render();
        assert!(r.contains("figX") && r.contains("conv2"));
        assert_eq!(f.value("conv1", "IN+OUT"), Some(2.5));
        assert_eq!(f.value("conv3", "IN"), None);
        assert_eq!(f.value("conv1", "BOGUS"), None);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_mismatch_panics() {
        let mut f = Figure::new("f", "t", &["a"]);
        f.row("r", vec![1.0, 2.0]);
    }

    #[test]
    fn json_shape() {
        let mut f = Figure::new("f", "t", &["a"]);
        f.row("r", vec![1.0]);
        let j = f.to_json();
        assert_eq!(j.get("id").as_str(), Some("f"));
        assert_eq!(j.get("rows").as_arr().unwrap().len(), 1);
    }

    #[test]
    fn non_finite_values_emit_null_and_na() {
        let mut f = Figure::new("f", "t", &["a", "b"]);
        f.row("r", vec![f64::NAN, 2.0]);
        let dump = f.to_json().dump();
        assert!(dump.contains("null"), "{dump}");
        assert!(!dump.contains("NaN"), "{dump}");
        // The dump must stay parseable JSON.
        assert!(Json::parse(&dump).is_ok());
        let r = f.render();
        assert!(r.contains("n/a"), "{r}");
        assert!(!r.contains("NaN"), "{r}");
    }
}
