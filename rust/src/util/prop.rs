//! Mini property-testing framework (proptest is unavailable offline).
//!
//! A property is a closure over a [`Gen`] that either returns `Ok(())` or
//! an error message. [`check`] runs it for a configurable number of cases
//! with independent RNG streams and, on failure, retries the failing seed
//! `AGOS_PROP_SEED` so failures are reproducible:
//!
//! ```text
//! property failed (case 37, seed 0x1234abcd): <message>
//! rerun with AGOS_PROP_SEED=0x1234abcd
//! ```

use super::rng::Pcg32;

/// Value generator handed to each property case.
pub struct Gen {
    pub rng: Pcg32,
}

impl Gen {
    /// usize in `[lo, hi]` inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.rng.below((hi - lo + 1) as u32) as usize
    }

    /// f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bernoulli(0.5)
    }

    /// Pick one element from a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u32) as usize]
    }

    /// Vector of `n` values from `f`.
    pub fn vec<T>(&mut self, n: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        (0..n).map(|_| f(self)).collect()
    }
}

/// Configuration for a property run.
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        let seed = std::env::var("AGOS_PROP_SEED")
            .ok()
            .and_then(|s| {
                let s = s.trim_start_matches("0x");
                u64::from_str_radix(s, 16).ok()
            })
            .unwrap_or(0xA605_2021);
        let cases = std::env::var("AGOS_PROP_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(64);
        Config { cases, seed }
    }
}

/// Run `prop` for `cfg.cases` independent cases; panic with a reproducible
/// seed on the first failure.
pub fn check_with(cfg: Config, name: &str, mut prop: impl FnMut(&mut Gen) -> Result<(), String>) {
    for case in 0..cfg.cases {
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen { rng: Pcg32::new(case_seed) };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed (case {case}): {msg}\n\
                 rerun with AGOS_PROP_SEED=0x{case_seed:x} AGOS_PROP_CASES=1"
            );
        }
    }
}

/// Run with the default configuration (env-overridable).
pub fn check(name: &str, prop: impl FnMut(&mut Gen) -> Result<(), String>) {
    check_with(Config::default(), name, prop);
}

/// Assertion helpers that produce `Result<(), String>` for properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", |g| {
            let a = g.usize_in(0, 1000);
            let b = g.usize_in(0, 1000);
            prop_assert!(a + b == b + a);
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check_with(Config { cases: 4, seed: 1 }, "always-fails", |_| Err("nope".into()));
    }

    #[test]
    fn gen_ranges_hold() {
        check("gen-ranges", |g| {
            let x = g.usize_in(5, 9);
            prop_assert!((5..=9).contains(&x), "x={x}");
            let f = g.f64_in(-1.0, 1.0);
            prop_assert!((-1.0..1.0).contains(&f), "f={f}");
            let v = g.vec(3, |g| g.bool());
            prop_assert!(v.len() == 3);
            Ok(())
        });
    }
}
