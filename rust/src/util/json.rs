//! Minimal JSON: a value type, a recursive-descent parser and a writer.
//!
//! Used for accelerator configs (`configs/*.json`), the AOT artifact
//! manifest, trace files and experiment results. Supports the full JSON
//! grammar except `\uXXXX` surrogate pairs beyond the BMP (sufficient for
//! this repo's ASCII-only files; non-BMP escapes error out loudly).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable
/// and diffs of result files are meaningful.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ----- constructors ---------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    // ----- accessors -------------------------------------------------------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as u64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|x| x as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]`-style access; returns `Json::Null` when missing.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Fetch a required field, with a path-aware error.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        match self {
            Json::Obj(o) => o.get(key).ok_or(JsonError {
                msg: format!("missing required key '{key}'"),
                offset: 0,
            }),
            _ => Err(JsonError { msg: format!("'{key}' on non-object"), offset: 0 }),
        }
    }

    pub fn set(&mut self, key: &str, v: Json) {
        if let Json::Obj(o) = self {
            o.insert(key.to_string(), v);
        }
    }

    pub fn push(&mut self, v: Json) {
        if let Json::Arr(a) = self {
            a.push(v);
        }
    }

    // ----- parse ------------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Ok(Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?)
    }

    // ----- write ------------------------------------------------------------
    /// Compact single-line form.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty form with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    pub fn write_file(&self, path: &std::path::Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.pretty())?;
        Ok(())
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    // Integral values print without the ".0" noise.
                    let _ = fmt::Write::write_fmt(out, format_args!("{}", *x as i64));
                } else {
                    let _ = fmt::Write::write_fmt(out, format_args!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(|x| x.into()).collect())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5]).unwrap();
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let c = char::from_u32(code)
                                .ok_or_else(|| self.err("surrogate \\u escape unsupported"))?;
                            s.push(c);
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut o = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(o));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            o.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(o));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":false}],"c":"x"}"#).unwrap();
        assert_eq!(j.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(j.get("a").as_arr().unwrap()[2].get("b"), &Json::Bool(false));
        assert_eq!(j.get("c").as_str(), Some("x"));
    }

    #[test]
    fn missing_key_is_null() {
        let j = Json::parse("{}").unwrap();
        assert_eq!(j.get("nope"), &Json::Null);
        assert!(j.req("nope").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"arr":[1,2.5,-3],"nested":{"k":"v","n":null},"s":"q\"uote","t":true}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&j.dump()).unwrap(), j);
        assert_eq!(Json::parse(&j.pretty()).unwrap(), j);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn integral_numbers_print_clean() {
        assert_eq!(Json::Num(5.0).dump(), "5");
        assert_eq!(Json::Num(5.25).dump(), "5.25");
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("agos_json_test");
        let path = dir.join("x.json");
        let j = Json::from_pairs(vec![("a", 1u64.into()), ("b", "two".into())]);
        j.write_file(&path).unwrap();
        assert_eq!(Json::parse_file(&path).unwrap(), j);
        std::fs::remove_dir_all(dir).ok();
    }
}
