//! Self-contained utility substrate.
//!
//! The build environment is offline with a minimal crate cache (see
//! DESIGN.md §0), so the pieces a project would normally pull from
//! crates.io — RNG, JSON, a CLI parser, a statistics/benchmark harness and
//! a property-testing loop — are implemented here, each small, documented
//! and unit-tested.

pub mod fnv;
pub mod pool;
pub mod rng;
pub mod json;
pub mod stats;
pub mod bench;
pub mod bench_gate;
pub mod cli;
pub mod prop;
pub mod log;
