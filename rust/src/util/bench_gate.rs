//! Perf-regression gate over the bench harness's JSON output.
//!
//! `benches/sim_hotpath.rs` persists a flat JSON object of measurements
//! (`BENCH_sweep.json`); a committed baseline (`BENCH_baseline.json`)
//! names the rows that are tracked, their reference values, and which
//! direction is "better". `agos bench-check` compares the two and fails
//! when any tracked row moves more than its tolerance in the worse
//! direction.
//!
//! The committed baseline deliberately tracks *ratio* rows (parallel
//! speedup, exact-vs-analytic slowdown, replay-vs-sampled, word-walk
//! speedup): ratios divide out the host's absolute speed, so one
//! baseline gates every machine — laptop and CI runner alike — where
//! absolute `*_mean_s` rows would need per-host blessing. Absolute rows
//! *can* be tracked; they just don't belong in a shared baseline.

use std::path::Path;

use anyhow::{Context, Result};

use super::json::Json;

/// Which way a tracked metric improves.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    /// Smaller is better (times, slowdown ratios).
    Lower,
    /// Larger is better (speedup ratios).
    Higher,
}

impl Direction {
    pub fn label(&self) -> &'static str {
        match self {
            Direction::Lower => "lower",
            Direction::Higher => "higher",
        }
    }

    pub fn parse(s: &str) -> Result<Direction> {
        match s.to_ascii_lowercase().as_str() {
            "lower" => Ok(Direction::Lower),
            "higher" => Ok(Direction::Higher),
            other => anyhow::bail!("unknown direction '{other}' (lower|higher)"),
        }
    }
}

/// One tracked row of the baseline.
#[derive(Clone, Debug)]
pub struct GateRow {
    /// Key in the bench JSON (e.g. "speedup").
    pub name: String,
    /// Reference value a regression is measured against.
    pub baseline: f64,
    pub better: Direction,
    /// Per-row tolerance override (fraction, e.g. 0.25 = 25%).
    pub tolerance: Option<f64>,
}

/// The committed perf baseline: tracked rows plus a default tolerance.
#[derive(Clone, Debug)]
pub struct BenchGate {
    pub bench: String,
    pub tolerance: f64,
    pub rows: Vec<GateRow>,
    /// Top-level fields other than bench/tolerance/rows ("note",
    /// "source", …) — carried through `bless()` verbatim so re-blessing
    /// never strips the baseline's self-documentation.
    extra: Vec<(String, Json)>,
}

/// Verdict for one tracked row.
#[derive(Clone, Debug)]
pub struct RowOutcome {
    pub name: String,
    pub baseline: f64,
    /// Measured value, `None` when the bench JSON lacks the row (always
    /// a failure — a silently dropped row is how gates rot).
    pub current: Option<f64>,
    /// The bound the row must stay within to pass.
    pub allowed: f64,
    pub regressed: bool,
}

impl BenchGate {
    pub fn from_json(j: &Json) -> Result<BenchGate> {
        let bench = j.get("bench").as_str().context("baseline.bench")?.to_string();
        let tolerance = j.get("tolerance").as_f64().unwrap_or(0.25);
        anyhow::ensure!(tolerance > 0.0, "baseline.tolerance must be positive");
        let mut rows = Vec::new();
        for r in j.get("rows").as_arr().context("baseline.rows")? {
            rows.push(GateRow {
                name: r.get("name").as_str().context("row.name")?.to_string(),
                baseline: r.get("baseline").as_f64().context("row.baseline")?,
                better: Direction::parse(
                    r.get("better").as_str().context("row.better")?,
                )?,
                tolerance: r.get("tolerance").as_f64(),
            });
        }
        anyhow::ensure!(!rows.is_empty(), "baseline tracks no rows");
        let extra = j
            .as_obj()
            .map(|obj| {
                obj.iter()
                    .filter(|(k, _)| !matches!(k.as_str(), "bench" | "tolerance" | "rows"))
                    .map(|(k, v)| (k.clone(), v.clone()))
                    .collect()
            })
            .unwrap_or_default();
        Ok(BenchGate { bench, tolerance, rows, extra })
    }

    pub fn load(path: &Path) -> Result<BenchGate> {
        BenchGate::from_json(&Json::parse_file(path)?)
            .with_context(|| format!("loading bench baseline {}", path.display()))
    }

    /// Compare every tracked row against the bench JSON's measurements.
    pub fn check(&self, current: &Json) -> Vec<RowOutcome> {
        self.rows
            .iter()
            .map(|row| {
                let tol = row.tolerance.unwrap_or(self.tolerance);
                let allowed = match row.better {
                    Direction::Lower => row.baseline * (1.0 + tol),
                    Direction::Higher => row.baseline * (1.0 - tol),
                };
                let current_v = current.get(&row.name).as_f64();
                let regressed = match current_v {
                    None => true,
                    Some(v) => match row.better {
                        Direction::Lower => v > allowed,
                        Direction::Higher => v < allowed,
                    },
                };
                RowOutcome {
                    name: row.name.clone(),
                    baseline: row.baseline,
                    current: current_v,
                    allowed,
                    regressed,
                }
            })
            .collect()
    }

    /// Re-bless: the same tracked rows and tolerances with baselines
    /// replaced by the current measurements. Errors if a tracked row is
    /// missing from the measurements (blessing must not drop coverage).
    pub fn bless(&self, current: &Json) -> Result<Json> {
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|row| {
                let v = current.get(&row.name).as_f64().with_context(|| {
                    format!("bench output lacks tracked row '{}'", row.name)
                })?;
                let mut r = Json::from_pairs(vec![
                    ("name", row.name.as_str().into()),
                    ("baseline", v.into()),
                    ("better", row.better.label().into()),
                ]);
                if let Some(t) = row.tolerance {
                    r.set("tolerance", t.into());
                }
                Ok(r)
            })
            .collect::<Result<_>>()?;
        let mut j = Json::from_pairs(vec![
            ("bench", self.bench.as_str().into()),
            ("tolerance", self.tolerance.into()),
        ]);
        for (k, v) in &self.extra {
            j.set(k, v.clone());
        }
        j.set("rows", Json::Arr(rows));
        Ok(j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn baseline() -> Json {
        Json::parse(
            r#"{
          "bench": "sim_hotpath",
          "note": "ratio rows only",
          "tolerance": 0.25,
          "rows": [
            {"name": "speedup", "baseline": 2.0, "better": "higher"},
            {"name": "slowdown", "baseline": 10.0, "better": "lower", "tolerance": 0.5}
          ]
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn passes_within_tolerance_fails_beyond() {
        let gate = BenchGate::from_json(&baseline()).unwrap();
        assert_eq!(gate.rows.len(), 2);
        // Both rows comfortably inside their bounds.
        let good = Json::from_pairs(vec![("speedup", 1.9.into()), ("slowdown", 12.0.into())]);
        assert!(gate.check(&good).iter().all(|o| !o.regressed));
        // speedup below 2.0·0.75 = 1.5 regresses; slowdown above
        // 10·1.5 = 15 regresses (per-row tolerance override).
        let bad_speed = Json::from_pairs(vec![("speedup", 1.4.into()), ("slowdown", 9.0.into())]);
        let out = gate.check(&bad_speed);
        assert!(out[0].regressed && !out[1].regressed);
        assert!((out[0].allowed - 1.5).abs() < 1e-12);
        let bad_slow = Json::from_pairs(vec![("speedup", 2.0.into()), ("slowdown", 15.1.into())]);
        let out = gate.check(&bad_slow);
        assert!(!out[0].regressed && out[1].regressed);
        assert!((out[1].allowed - 15.0).abs() < 1e-12);
        // Better-than-baseline never fails.
        let fast = Json::from_pairs(vec![("speedup", 9.0.into()), ("slowdown", 0.1.into())]);
        assert!(gate.check(&fast).iter().all(|o| !o.regressed));
    }

    #[test]
    fn missing_rows_fail_and_blessing_preserves_coverage() {
        let gate = BenchGate::from_json(&baseline()).unwrap();
        let partial = Json::from_pairs(vec![("speedup", 2.0.into())]);
        let out = gate.check(&partial);
        assert!(!out[0].regressed);
        assert!(out[1].regressed, "missing tracked row must fail");
        assert!(out[1].current.is_none());
        // bless() refuses incomplete measurements…
        assert!(gate.bless(&partial).is_err());
        // …and otherwise rewrites baselines in place, keeping overrides.
        let full = Json::from_pairs(vec![("speedup", 3.0.into()), ("slowdown", 8.0.into())]);
        let blessed = gate.bless(&full).unwrap();
        let gate2 = BenchGate::from_json(&blessed).unwrap();
        assert_eq!(gate2.rows[0].baseline, 3.0);
        assert_eq!(gate2.rows[1].baseline, 8.0);
        assert_eq!(gate2.rows[1].tolerance, Some(0.5));
        assert!(gate2.check(&full).iter().all(|o| !o.regressed));
        // Self-documentation fields survive re-blessing verbatim.
        assert_eq!(blessed.get("note").as_str(), Some("ratio rows only"));
    }

    #[test]
    fn malformed_baselines_are_rejected() {
        assert!(BenchGate::from_json(&Json::parse(r#"{"bench":"x","rows":[]}"#).unwrap()).is_err());
        let sideways =
            r#"{"bench":"x","rows":[{"name":"a","baseline":1.0,"better":"sideways"}]}"#;
        assert!(BenchGate::from_json(&Json::parse(sideways).unwrap()).is_err());
        assert!(Direction::parse("HIGHER").is_ok());
    }
}
