//! Indexed scoped worker pool — the one claim-an-index/collect-by-index
//! idiom behind the sweep executor and the engine's per-image fan-out.
//!
//! Workers claim indices from a shared atomic counter and send
//! `(index, result)` pairs back over a channel; the caller's thread
//! collects them into a `Vec` slot per index. Output order is therefore
//! a pure function of the input — deterministic regardless of how the
//! OS schedules the workers — which is what lets the simulator promise
//! bit-identical results at any `--jobs` level.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::thread;

/// Evaluate `f(i)` for `i in 0..n` on up to `jobs` scoped worker
/// threads; returns the results indexed by `i`. `jobs <= 1` (or
/// `n <= 1`) degrades to a plain sequential loop with no thread
/// machinery. A panicking `f` propagates out of the scope.
pub fn run_indexed<T, F>(n: usize, jobs: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let jobs = jobs.clamp(1, n.max(1));
    if jobs <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel();
    thread::scope(|s| {
        for _ in 0..jobs {
            let tx = tx.clone();
            let next = &next;
            let f = &f;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                if tx.send((i, f(i))).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        while let Ok((i, r)) = rx.recv() {
            out[i] = Some(r);
        }
    });
    out.into_iter().map(|r| r.expect("worker pool covered every index")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_are_indexed_and_complete() {
        for jobs in [0, 1, 3, 16] {
            let out = run_indexed(10, jobs, |i| i * i);
            assert_eq!(out, (0..10).map(|i| i * i).collect::<Vec<_>>(), "jobs={jobs}");
        }
        assert!(run_indexed(0, 4, |i| i).is_empty());
    }

    #[test]
    fn every_index_runs_exactly_once() {
        use std::sync::atomic::AtomicU32;
        let counts: Vec<AtomicU32> = (0..64).map(|_| AtomicU32::new(0)).collect();
        run_indexed(64, 8, |i| counts[i].fetch_add(1, Ordering::Relaxed));
        for (i, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Ordering::Relaxed), 1, "index {i}");
        }
    }
}
