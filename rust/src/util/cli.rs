//! Declarative command-line argument parsing (clap is unavailable offline).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value` options
//! and positional arguments, with generated `--help` text.

use std::collections::BTreeMap;

/// Parsed arguments for one (sub)command.
#[derive(Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.opt(key).unwrap_or(default)
    }

    pub fn opt_usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn opt_f64(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got '{v}'")),
        }
    }

    pub fn opt_u64(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// Option/flag specification for help generation and validation.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub help: &'static str,
}

/// One subcommand.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

/// A tiny clap-like application description.
pub struct App {
    pub name: &'static str,
    pub about: &'static str,
    pub commands: Vec<Command>,
}

/// Result of a successful parse: subcommand name + its args.
pub struct Parsed {
    pub command: String,
    pub args: Args,
}

impl App {
    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} <command> [options]\n\nCOMMANDS:\n", self.name, self.about, self.name);
        for c in &self.commands {
            s.push_str(&format!("  {:<12} {}\n", c.name, c.about));
        }
        s.push_str("\nRun `");
        s.push_str(self.name);
        s.push_str(" <command> --help` for command options.\n");
        s
    }

    pub fn command_help(&self, cmd: &Command) -> String {
        let mut s = format!("{} {} — {}\n\nOPTIONS:\n", self.name, cmd.name, cmd.about);
        for o in &cmd.opts {
            let lhs = if o.takes_value { format!("--{} <value>", o.name) } else { format!("--{}", o.name) };
            s.push_str(&format!("  {:<24} {}\n", lhs, o.help));
        }
        s
    }

    /// Parse `argv` (excluding the binary name). Returns `Err` with the
    /// help/usage text on any problem, and `Ok(None)` when help was
    /// explicitly requested (caller should print and exit 0).
    pub fn parse(&self, argv: &[String]) -> Result<Option<Parsed>, String> {
        if argv.is_empty() {
            return Err(self.help());
        }
        let first = argv[0].as_str();
        if first == "--help" || first == "-h" || first == "help" {
            println!("{}", self.help());
            return Ok(None);
        }
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == first)
            .ok_or_else(|| format!("unknown command '{first}'\n\n{}", self.help()))?;

        let mut args = Args::default();
        let mut i = 1;
        while i < argv.len() {
            let a = argv[i].as_str();
            if a == "--help" || a == "-h" {
                println!("{}", self.command_help(cmd));
                return Ok(None);
            }
            if let Some(body) = a.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (body, None),
                };
                let spec = cmd
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| format!("unknown option '--{key}' for '{}'\n\n{}", cmd.name, self.command_help(cmd)))?;
                if spec.takes_value {
                    let v = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("option '--{key}' expects a value"))?
                        }
                    };
                    args.opts.insert(key.to_string(), v);
                } else {
                    if inline_val.is_some() {
                        return Err(format!("flag '--{key}' does not take a value"));
                    }
                    args.flags.push(key.to_string());
                }
            } else {
                args.positional.push(a.to_string());
            }
            i += 1;
        }
        Ok(Some(Parsed { command: cmd.name.to_string(), args }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn app() -> App {
        App {
            name: "agos",
            about: "test",
            commands: vec![Command {
                name: "run",
                about: "run things",
                opts: vec![
                    OptSpec { name: "steps", takes_value: true, help: "step count" },
                    OptSpec { name: "fast", takes_value: false, help: "go fast" },
                ],
            }],
        }
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_opts_flags_positionals() {
        let p = app().parse(&sv(&["run", "--steps", "5", "--fast", "pos1"])).unwrap().unwrap();
        assert_eq!(p.command, "run");
        assert_eq!(p.args.opt_usize("steps", 0).unwrap(), 5);
        assert!(p.args.flag("fast"));
        assert_eq!(p.args.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn equals_syntax() {
        let p = app().parse(&sv(&["run", "--steps=9"])).unwrap().unwrap();
        assert_eq!(p.args.opt("steps"), Some("9"));
    }

    #[test]
    fn unknown_command_and_option_error() {
        assert!(app().parse(&sv(&["nope"])).is_err());
        assert!(app().parse(&sv(&["run", "--bogus", "1"])).is_err());
        assert!(app().parse(&sv(&["run", "--steps"])).is_err());
        assert!(app().parse(&sv(&["run", "--fast=1"])).is_err());
    }

    #[test]
    fn defaults_apply() {
        let p = app().parse(&sv(&["run"])).unwrap().unwrap();
        assert_eq!(p.args.opt_usize("steps", 3).unwrap(), 3);
        assert_eq!(p.args.opt_or("steps", "x"), "x");
        assert!(!p.args.flag("fast"));
    }

    #[test]
    fn bad_value_type_errors() {
        let p = app().parse(&sv(&["run", "--steps", "abc"])).unwrap().unwrap();
        assert!(p.args.opt_usize("steps", 0).is_err());
    }
}
