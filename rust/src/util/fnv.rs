//! 64-bit FNV-1a folding — the one hashing implementation behind name
//! hashing and every fingerprint that feeds the sweep cache key
//! (`AcceleratorConfig`, `SimOptions`, `SparsityModel`, `Network`).
//! Keeping a single copy guarantees cache-key components can never
//! desynchronize.

const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const PRIME: u64 = 0x0000_0100_0000_01B3;

/// Incremental FNV-1a hasher. `put_bytes` is the classic byte-wise
/// FNV-1a; `put`/`put_f64` fold whole words (the fingerprint variant).
#[derive(Clone, Debug)]
pub struct Fnv1a(u64);

impl Fnv1a {
    pub fn new() -> Fnv1a {
        Fnv1a(OFFSET)
    }

    #[inline]
    pub fn put(&mut self, x: u64) -> &mut Fnv1a {
        self.0 = (self.0 ^ x).wrapping_mul(PRIME);
        self
    }

    #[inline]
    pub fn put_f64(&mut self, x: f64) -> &mut Fnv1a {
        self.put(x.to_bits())
    }

    #[inline]
    pub fn put_bytes(&mut self, bytes: &[u8]) -> &mut Fnv1a {
        for &b in bytes {
            self.put(b as u64);
        }
        self
    }

    /// Hash a string plus its length, so adjacent strings cannot alias
    /// ("ab","c" vs "a","bc").
    #[inline]
    pub fn put_str(&mut self, s: &str) -> &mut Fnv1a {
        self.put_bytes(s.as_bytes());
        self.put(s.len() as u64)
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv1a {
    fn default() -> Self {
        Fnv1a::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_reference_fnv1a_vectors() {
        // Published FNV-1a 64 test vectors.
        assert_eq!(Fnv1a::new().finish(), 0xcbf2_9ce4_8422_2325);
        let mut h = Fnv1a::new();
        h.put_bytes(b"a");
        assert_eq!(h.finish(), 0xaf63_dc4c_8601_ec8c);
        let mut h = Fnv1a::new();
        h.put_bytes(b"foobar");
        assert_eq!(h.finish(), 0x85944171f73967e8);
    }

    #[test]
    fn put_str_separates_boundaries() {
        let mut a = Fnv1a::new();
        a.put_str("ab").put_str("c");
        let mut b = Fnv1a::new();
        b.put_str("a").put_str("bc");
        assert_ne!(a.finish(), b.finish());
    }

    #[test]
    fn word_and_float_folds_differ_by_input() {
        let mut a = Fnv1a::new();
        a.put(1).put_f64(0.5);
        let mut b = Fnv1a::new();
        b.put(1).put_f64(0.25);
        assert_ne!(a.finish(), b.finish());
    }
}
