//! Descriptive statistics over `f64` samples.
//!
//! Shared by the bench harness, the node-utilization figures (min/avg/max
//! tile latency) and the sparsity reports (Fig 3d min/avg/max over a
//! batch).

/// Summary statistics of a sample set.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n as f64 - 1.0)
        } else {
            0.0
        };
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = if n % 2 == 1 {
            sorted[n / 2]
        } else {
            0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
        };
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median,
        }
    }
}

/// Percentile by linear interpolation, `q` in `[0, 100]`.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q / 100.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Geometric mean (used for "mean speedup" aggregation, the convention in
/// architecture papers).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Weighted arithmetic mean.
pub fn weighted_mean(pairs: &[(f64, f64)]) -> f64 {
    let wsum: f64 = pairs.iter().map(|(_, w)| w).sum();
    assert!(wsum > 0.0);
    pairs.iter().map(|(x, w)| x * w).sum::<f64>() / wsum
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.median - 2.5).abs() < 1e-12);
        assert!((s.std - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_single() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.median, 7.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert!((percentile(&xs, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_speedups() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 1.0, 1.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn weighted_mean_works() {
        assert!((weighted_mean(&[(1.0, 1.0), (3.0, 3.0)]) - 2.5).abs() < 1e-12);
    }
}
