//! Criterion-style measurement harness for the `benches/` targets.
//!
//! The offline crate cache has no `criterion`, so this provides the same
//! core loop: warm-up, timed iterations until a wall-clock budget is met,
//! and a mean ± std report — plus a `black_box` re-export to prevent
//! constant folding. Benches are declared `harness = false` in Cargo.toml
//! and call [`Bench::run`] from `main`.

use std::time::{Duration, Instant};

pub use std::hint::black_box;

use super::stats::Summary;

/// One benchmark group; prints results in a compact table.
pub struct Bench {
    name: String,
    warmup: Duration,
    budget: Duration,
    min_iters: u32,
    results: Vec<(String, Summary)>,
}

impl Bench {
    pub fn new(name: &str) -> Bench {
        // Fast-mode envvar for CI/`cargo bench` smoke runs.
        let quick = std::env::var("AGOS_BENCH_QUICK").is_ok();
        Bench {
            name: name.to_string(),
            warmup: if quick { Duration::from_millis(20) } else { Duration::from_millis(200) },
            budget: if quick { Duration::from_millis(100) } else { Duration::from_secs(2) },
            min_iters: if quick { 3 } else { 10 },
            results: Vec::new(),
        }
    }

    pub fn with_budget(mut self, budget: Duration) -> Bench {
        self.budget = budget;
        self
    }

    /// Measure `f`, which should perform one complete unit of work and
    /// return a value (fed through `black_box`).
    pub fn case<T>(&mut self, label: &str, mut f: impl FnMut() -> T) {
        // Warm-up phase.
        let t0 = Instant::now();
        while t0.elapsed() < self.warmup {
            black_box(f());
        }
        // Measurement phase.
        let mut samples = Vec::new();
        let t1 = Instant::now();
        while t1.elapsed() < self.budget || samples.len() < self.min_iters as usize {
            let s = Instant::now();
            black_box(f());
            samples.push(s.elapsed().as_secs_f64());
            if samples.len() >= 10_000 {
                break;
            }
        }
        let summary = Summary::of(&samples);
        println!(
            "{:<48} {:>12} ± {:>10}   (n={}, min {}, max {})",
            format!("{}/{}", self.name, label),
            fmt_dur(summary.mean),
            fmt_dur(summary.std),
            summary.n,
            fmt_dur(summary.min),
            fmt_dur(summary.max),
        );
        self.results.push((label.to_string(), summary));
    }

    /// Access collected results (label, summary).
    pub fn results(&self) -> &[(String, Summary)] {
        &self.results
    }

    /// Print a closing separator.
    pub fn finish(&self) {
        println!("{} done ({} cases)", self.name, self.results.len());
    }
}

/// Human duration from seconds.
pub fn fmt_dur(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_collects() {
        std::env::set_var("AGOS_BENCH_QUICK", "1");
        let mut b = Bench::new("t").with_budget(Duration::from_millis(10));
        b.case("noop", || 1 + 1);
        assert_eq!(b.results().len(), 1);
        assert!(b.results()[0].1.n >= 3);
    }

    #[test]
    fn fmt_dur_scales() {
        assert!(fmt_dur(2.0).ends_with(" s"));
        assert!(fmt_dur(2e-3).ends_with(" ms"));
        assert!(fmt_dur(2e-6).ends_with(" µs"));
        assert!(fmt_dur(2e-9).ends_with(" ns"));
    }
}
