//! Deterministic pseudo-random number generation.
//!
//! Two small generators cover every stochastic need of the simulator and
//! the synthetic-trace generator:
//!
//! * [`SplitMix64`] — seeding / hashing / stream splitting.
//! * [`Pcg32`] — the workhorse stream generator (PCG-XSH-RR 64/32),
//!   statistically solid and fast enough for per-output-neuron sampling.
//!
//! Both are fully deterministic from their seed, which keeps every
//! experiment in `EXPERIMENTS.md` reproducible bit-for-bit.

/// SplitMix64: tiny, passes BigCrush, ideal for seeding other generators.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSH-RR 64/32 — the default stream generator.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6_364_136_223_846_793_005;

impl Pcg32 {
    /// Seed with an arbitrary `u64`; the stream id is derived via SplitMix.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self::with_stream(sm.next_u64(), sm.next_u64())
    }

    /// Explicit (state, stream) construction; `stream` picks one of 2^63
    /// independent sequences.
    pub fn with_stream(initstate: u64, initseq: u64) -> Self {
        let mut rng = Self { state: 0, inc: (initseq << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(initstate);
        rng.next_u32();
        rng
    }

    /// Derive an independent child generator (used to give each PE tile /
    /// batch image its own stream without correlation).
    pub fn split(&mut self, tag: u64) -> Pcg32 {
        let mut sm = SplitMix64::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        Pcg32::with_stream(sm.next_u64(), sm.next_u64())
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)` with 32 bits of resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        self.next_u32() as f64 * (1.0 / 4_294_967_296.0)
    }

    /// Uniform in `[0, 1)` as `f32`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / 16_777_216.0)
    }

    /// Unbiased uniform integer in `[0, bound)` (Lemire's method).
    #[inline]
    pub fn below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (bound as u64);
        let mut l = m as u32;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (bound as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform in `[lo, hi)` for f64.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli draw with success probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (single value; simple and adequate).
    pub fn gauss(&mut self) -> f64 {
        // Rejection-free Box–Muller; avoid u==0 for the log.
        let u = (self.next_u32() as f64 + 1.0) * (1.0 / 4_294_967_297.0);
        let v = self.f64();
        (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos()
    }

    /// Binomial(n, p) sample.
    ///
    /// Exact inversion for small `n`, normal approximation (with
    /// continuity correction, clamped) for large `n` — the large-`n` case
    /// is the per-output-neuron NZ-count draw where `n = C·R·S` can reach
    /// tens of thousands, so speed matters and the approximation error is
    /// far below the simulator's modeling error.
    pub fn binomial(&mut self, n: u32, p: f64) -> u32 {
        if p <= 0.0 || n == 0 {
            return 0;
        }
        if p >= 1.0 {
            return n;
        }
        let nf = n as f64;
        let mean = nf * p;
        let var = nf * p * (1.0 - p);
        if n <= 16 {
            // direct Bernoulli sum
            let mut k = 0;
            for _ in 0..n {
                if self.bernoulli(p) {
                    k += 1;
                }
            }
            k
        } else if var < 25.0 {
            // Inversion from the CDF — cheap when variance is small.
            let q = 1.0 - p;
            let s = p / q;
            let a = (nf + 1.0) * s;
            let mut r = q.powf(nf);
            let u0 = self.f64();
            let mut u = u0;
            let mut x = 0u32;
            loop {
                if u < r {
                    return x.min(n);
                }
                u -= r;
                x += 1;
                if x > n {
                    return n;
                }
                r *= a / (x as f64) - s;
            }
        } else {
            let z = self.gauss();
            let k = (mean + z * var.sqrt() + 0.5).floor();
            k.clamp(0.0, nf) as u32
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn pcg_streams_differ() {
        let mut a = Pcg32::with_stream(1, 1);
        let mut b = Pcg32::with_stream(1, 2);
        let va: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let vb: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg32::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Pcg32::new(3);
        let mut counts = [0u32; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "count {c} far from uniform");
        }
    }

    #[test]
    fn binomial_mean_and_bounds() {
        let mut r = Pcg32::new(11);
        for &(n, p) in &[(8u32, 0.3), (100, 0.45), (5000, 0.6), (40000, 0.01)] {
            let trials = 3000;
            let mut sum = 0u64;
            for _ in 0..trials {
                let k = r.binomial(n, p);
                assert!(k <= n);
                sum += k as u64;
            }
            let mean = sum as f64 / trials as f64;
            let expect = n as f64 * p;
            let sd = (n as f64 * p * (1.0 - p)).sqrt();
            assert!(
                (mean - expect).abs() < 4.0 * sd / (trials as f64).sqrt() + 0.5,
                "n={n} p={p}: mean {mean} vs {expect}"
            );
        }
    }

    #[test]
    fn binomial_degenerate() {
        let mut r = Pcg32::new(1);
        assert_eq!(r.binomial(10, 0.0), 0);
        assert_eq!(r.binomial(10, 1.0), 10);
        assert_eq!(r.binomial(0, 0.5), 0);
    }

    #[test]
    fn gauss_moments() {
        let mut r = Pcg32::new(5);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.gauss();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(9);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
