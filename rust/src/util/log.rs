//! Leveled stderr logging with an env filter (`AGOS_LOG=debug|info|warn`).
//!
//! Deliberately tiny: the coordinator and the long-running sweeps use it
//! for progress lines; everything that is a *result* goes through
//! `report::*` to stdout instead.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // unset

fn level() -> Level {
    let v = LEVEL.load(Ordering::Relaxed);
    if v == u8::MAX {
        let parsed = match std::env::var("AGOS_LOG").as_deref() {
            Ok("debug") => Level::Debug,
            Ok("warn") => Level::Warn,
            _ => Level::Info,
        };
        LEVEL.store(parsed as u8, Ordering::Relaxed);
        parsed
    } else {
        match v {
            0 => Level::Debug,
            1 => Level::Info,
            _ => Level::Warn,
        }
    }
}

/// Override the level programmatically (tests).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

pub fn log(l: Level, msg: std::fmt::Arguments<'_>) {
    if l < level() {
        return;
    }
    let t0 = START.get_or_init(Instant::now);
    let tag = match l {
        Level::Debug => "DBG",
        Level::Info => "INF",
        Level::Warn => "WRN",
    };
    eprintln!("[{:>9.3}s {tag}] {msg}", t0.elapsed().as_secs_f64());
}

#[macro_export]
macro_rules! debug {
    ($($t:tt)+) => { $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($t)+)) };
}
#[macro_export]
macro_rules! info {
    ($($t:tt)+) => { $crate::util::log::log($crate::util::log::Level::Info, format_args!($($t)+)) };
}
#[macro_export]
macro_rules! warn_ {
    ($($t:tt)+) => { $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($t)+)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order() {
        assert!(Level::Debug < Level::Info);
        assert!(Level::Info < Level::Warn);
    }

    #[test]
    fn set_level_silences_lower() {
        set_level(Level::Warn);
        // Just exercise the paths; output is on stderr.
        log(Level::Debug, format_args!("hidden"));
        log(Level::Warn, format_args!("shown"));
        set_level(Level::Info);
    }
}
