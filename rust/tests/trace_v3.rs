//! TraceFile v3 conformance suite (ISSUE 5 acceptance):
//!
//! * **Codec**: the delta/RLE word encoding round-trips bit-identically
//!   (property-style over iid, blobbed, all-zero and all-ones maps),
//!   and a committed v1/v2/v3 fixture corpus under `tests/data/` pins
//!   the on-disk grammar against accidental format drift.
//! * **Size**: on the blob pattern a v3 payload is ≤ 1/3 of the v2 hex
//!   payload — the property that makes `--trace-images N` batch-wide
//!   capture practical.
//! * **Equivalence**: the same capture saved as v2 and as v3 replays to
//!   bit-identical co-simulation rows — the encoding changes bytes,
//!   never results.
//! * **Residual replay**: a v3 trace of `agos_resnet` (post-Add
//!   footprints + Add-pass-through gradient maps) replays the Add-fed
//!   BP tail with zero RNG draws, bit-identical at any `--jobs` level.
//! * **Cache soundness**: the same content under different formats (and
//!   different patterns at the same means) never shares a sweep-cache
//!   entry.
//! * **Robustness**: corrupt/truncated v3 payloads error with layer and
//!   step context on the strict path and drop-with-warning on the
//!   lenient path `agos cosim` uses.

use std::path::{Path, PathBuf};

use agos::config::{AcceleratorConfig, BitmapPattern, ExecBackend, Scheme, SimOptions};
use agos::coordinator::cosim_from_traces;
use agos::nn::{zoo, Shape};
use agos::sim::{simulate_network, ReplayBank, SweepKey};
use agos::sparsity::{
    capture_synthetic_trace, capture_synthetic_trace_images, Bitmap, SparsityModel,
};
use agos::trace::{LayerTrace, StepTrace, TraceFile, TraceFormat};
use agos::util::json::Json;
use agos::util::rng::Pcg32;

fn fixture(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/data").join(name)
}

/// Total characters of bitmap payload (`words` fields) in a serialized
/// trace — the quantity the v3 encoding exists to shrink.
fn payload_chars(j: &Json) -> usize {
    let mut total = 0usize;
    for s in j.get("steps").as_arr().expect("steps") {
        for l in s.get("layers").as_arr().expect("layers") {
            for slot in ["act_bitmap", "grad_bitmap"] {
                if let Some(w) = l.get(slot).get("words").as_str() {
                    total += w.len();
                }
            }
        }
    }
    total
}

#[test]
fn rle_roundtrip_is_bit_identical_property_style() {
    // iid + blobbed + degenerate maps across ragged and aligned shapes;
    // every encode→decode must reproduce the exact words.
    let shapes = [
        Shape::new(16, 32, 32), // word-aligned
        Shape::new(3, 7, 9),    // 189-bit ragged tail
        Shape::new(64, 1, 1),   // channel-per-bit (GAP-shaped)
        Shape::new(1, 1, 1),    // single bit
    ];
    let mut rng = Pcg32::new(0xC0DE);
    for shape in shapes {
        for density in [0.0, 0.02, 0.25, 0.5, 0.85, 1.0] {
            for radius in [0usize, 2, 4] {
                let maps = [
                    Bitmap::sample(shape, density, &mut rng),
                    Bitmap::sample_blobs(shape, density, radius, &mut rng),
                ];
                for b in maps {
                    let enc = b.encode_rle();
                    let back = Bitmap::decode_rle(shape, &enc).unwrap();
                    assert_eq!(b, back, "shape {shape} density {density} radius {radius}");
                    // Hex and RLE describe the same words.
                    assert_eq!(Bitmap::decode_hex(shape, &b.encode_hex()).unwrap(), back);
                }
            }
        }
    }
}

#[test]
fn fixture_corpus_loads_across_revisions() {
    // v1: scalar-only, no version key.
    let v1 = TraceFile::load(&fixture("trace_v1.json")).unwrap();
    assert_eq!(v1.network, "fixture_net");
    assert!(!v1.has_bitmaps());
    assert_eq!(v1.format, TraceFormat::V2, "v1 loads re-save as v2");
    assert!((v1.steps[0].layers[0].act_sparsity - 0.5).abs() < 1e-12);

    // v2: raw hex payloads. Pin the decoded bits, not just "it loads".
    let v2 = TraceFile::load(&fixture("trace_v2.json")).unwrap();
    let act = v2.steps[0].layers[0].act_bitmap.as_ref().unwrap();
    assert_eq!(act.shape, Shape::new(2, 3, 3));
    assert_eq!(act.words(), &[0x15555]);
    let grad = v2.steps[0].layers[0].grad_bitmap.as_ref().unwrap();
    assert_eq!(grad.words(), &[0x11115]);
    assert!(grad.contained_in(act), "fixture satisfies the §3.2 identity");

    // v3: rle + delta payloads, incl. an act-only post-Add entry.
    let v3 = TraceFile::load(&fixture("trace_v3.json")).unwrap();
    assert_eq!(v3.format, TraceFormat::V3);
    assert_eq!(v3.steps.len(), 2);
    let s0 = &v3.steps[0];
    let r1 = s0.layers.iter().find(|l| l.name == "relu1").unwrap();
    assert_eq!(r1.act_bitmap.as_ref().unwrap().words(), &[0x15555]);
    assert_eq!(r1.grad_bitmap.as_ref().unwrap().words(), &[0x11115]);
    let r2 = s0.layers.iter().find(|l| l.name == "relu2").unwrap();
    assert_eq!(r2.act_bitmap.as_ref().unwrap().count_nz(), 0, "z-run decodes all-zero");
    let add = s0.layers.iter().find(|l| l.name == "add1").unwrap();
    assert_eq!(add.act_bitmap.as_ref().unwrap().count_nz(), 18, "o-run decodes all-ones");
    assert!(add.grad_bitmap.is_none(), "post-Add entries are act-only");
    assert!(add.footprint, "act-only entries infer the footprint marker");
    assert!(!r1.footprint);
    // Footprints are layout data: the per-layer means exclude them.
    assert!(!v3.mean_act_sparsity().contains_key("add1"));
    assert!(v3.mean_act_sparsity().contains_key("relu1"));
    // Step 1 chains deltas: act flips exactly bit 1, grad repeats.
    let s1r1 = v3.steps[1].layers.iter().find(|l| l.name == "relu1").unwrap();
    assert_eq!(s1r1.act_bitmap.as_ref().unwrap().words(), &[0x15557]);
    assert_eq!(s1r1.grad_bitmap.as_ref().unwrap().words(), &[0x11115]);
    let s1add = v3.steps[1].layers.iter().find(|l| l.name == "add1").unwrap();
    assert_eq!(s1add.act_bitmap.as_ref().unwrap().count_nz(), 18);

    // Re-saving every fixture round-trips bit-exactly in memory.
    for t in [&v1, &v2, &v3] {
        assert_eq!(TraceFile::from_json(&t.to_json()).unwrap(), *t);
    }
}

#[test]
fn v3_payload_is_at_most_a_third_of_v2_on_the_blob_pattern() {
    // Batch-wide capture of a realistically sparse blobbed map: two
    // images whose footprints are strongly correlated step to step
    // (what consecutive captures of a training run look like).
    let shape = Shape::new(32, 32, 32);
    let mut rng = Pcg32::new(7);
    let act0 = Bitmap::sample_blobs(shape, 0.04, 4, &mut rng);
    let keep = Bitmap::sample(shape, 0.5, &mut rng);
    let grad0 = act0.and(&keep);
    // Step 1 = step 0 with a handful of flipped sites.
    let mut act1 = act0.clone();
    for i in 0..20usize {
        let (c, y, x) = (i % 32, (i * 7) % 32, (i * 13) % 32);
        act1.set(c, y, x, !act1.get(c, y, x));
    }
    let grad1 = grad0.clone();
    let mk = |format: TraceFormat| TraceFile {
        network: "blob_bench".into(),
        steps: vec![
            StepTrace {
                step: 0,
                loss: 2.0,
                layers: vec![LayerTrace::from_bitmaps("relu1", act0.clone(), grad0.clone())],
            },
            StepTrace {
                step: 0,
                loss: 2.0,
                layers: vec![LayerTrace::from_bitmaps("relu1", act1.clone(), grad1.clone())],
            },
        ],
        format,
    };
    let v2_chars = payload_chars(&mk(TraceFormat::V2).to_json());
    let v3_chars = payload_chars(&mk(TraceFormat::V3).to_json());
    assert!(
        v3_chars * 3 <= v2_chars,
        "v3 payload must be <= 1/3 of v2 on the blob pattern: {v3_chars} vs {v2_chars}"
    );
    // And both decode back to the same maps.
    let a = TraceFile::from_json(&mk(TraceFormat::V2).to_json()).unwrap();
    let b = TraceFile::from_json(&mk(TraceFormat::V3).to_json()).unwrap();
    assert_eq!(a.steps, b.steps);
}

#[test]
fn v3_replay_equals_v2_replay_cosim_golden() {
    // The encoding must never change a result: the same capture saved
    // as v2 and v3, re-loaded from disk, co-simulates to identical rows
    // on both backends.
    let dir = std::env::temp_dir().join("agos_trace_v3_golden");
    std::fs::remove_dir_all(&dir).ok();
    let net = zoo::agos_resnet();
    let model = SparsityModel::synthetic(0xA605);
    let capture = capture_synthetic_trace(&net, &model, 2, BitmapPattern::Blobs, 2);
    let mut loaded = Vec::new();
    for format in TraceFormat::ALL {
        let mut t = capture.clone();
        t.format = format;
        let path = dir.join(format!("trace-{}.json", format.label()));
        t.save(&path).unwrap();
        loaded.push(TraceFile::load(&path).unwrap());
    }
    assert_eq!(loaded[0].steps, loaded[1].steps, "decoded content identical");
    assert_eq!(loaded[1].steps, loaded[2].steps, "v4 binary decodes the same content");
    assert_eq!(loaded[2].format, TraceFormat::V4);
    let cfg = AcceleratorConfig::default();
    for backend in [ExecBackend::Exact, ExecBackend::Analytic] {
        let opts = SimOptions {
            batch: 2,
            backend,
            exact_outputs_per_tile: 16,
            ..SimOptions::default()
        };
        let r2 = cosim_from_traces(&loaded[0], &cfg, &opts, true, 0).unwrap();
        let r3 = cosim_from_traces(&loaded[1], &cfg, &opts, true, 0).unwrap();
        let r4 = cosim_from_traces(&loaded[2], &cfg, &opts, true, 0).unwrap();
        assert_eq!(r2.rows, r3.rows, "{backend:?}: v2 and v3 replay must agree bit-for-bit");
        assert_eq!(r3.rows, r4.rows, "{backend:?}: v4 replay must agree bit-for-bit");
        assert!(r2.replayed && r3.replayed && r4.replayed);
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn residual_add_fed_bp_tail_replays_with_zero_rng_at_any_jobs_level() {
    // The acceptance bar: a v3 trace of the BN-free residual network
    // resolves every sparsity-bearing task — including b1_conv2, whose
    // gradient arrives through the residual Add, and the fc head fed
    // through GAP(post-Add) — so replay draws no RNG (the engine's
    // per-image stream seed cannot change any result) and is
    // bit-identical across --jobs levels.
    let net = zoo::agos_resnet();
    let model = SparsityModel::synthetic(11);
    let trace = capture_synthetic_trace(&net, &model, 2, BitmapPattern::Blobs, 2);
    let cfg = AcceleratorConfig::default();
    for backend in [ExecBackend::Exact, ExecBackend::Analytic] {
        // Fixed model, varying stream seed: only RNG draws could differ.
        let mk = |seed: u64| SimOptions {
            seed,
            batch: 3,
            backend,
            exact_outputs_per_tile: 16,
            trace_fingerprint: Some(trace.fingerprint()),
            replay: Some(std::sync::Arc::new(ReplayBank::from_trace(&net, &trace).unwrap())),
            ..SimOptions::default()
        };
        for scheme in Scheme::ALL {
            let a = simulate_network(&net, &cfg, &mk(1), &model, scheme);
            let b = simulate_network(&net, &cfg, &mk(0xDEAD_BEEF), &model, scheme);
            assert_eq!(
                a.total_cycles(),
                b.total_cycles(),
                "{backend:?}/{}: residual replay must be seed-independent (zero RNG)",
                scheme.label()
            );
            assert_eq!(a.total_energy_j(), b.total_energy_j());
            for (x, y) in a.per_layer.iter().zip(&b.per_layer) {
                assert_eq!(x.cycles, y.cycles, "{backend:?} {} {}", x.name, x.phase.label());
                assert_eq!(x.performed_macs, y.performed_macs);
            }
        }
        // End-to-end: the same replay cosim at --jobs 1 and --jobs 4 is
        // bit-identical (the CI report-diff contract, driver-level).
        let opts = SimOptions {
            batch: 3,
            backend,
            exact_outputs_per_tile: 16,
            ..SimOptions::default()
        };
        let j1 = cosim_from_traces(&trace, &cfg, &opts, true, 1).unwrap();
        let j4 = cosim_from_traces(&trace, &cfg, &opts, true, 4).unwrap();
        assert_eq!(j1.rows, j4.rows, "{backend:?}: jobs must not change replay");
        assert!(j1.replayed);
    }
    // Contrast (the test's teeth): strip the Add entries — the v2-era
    // capture — and the Add-fed BP tail falls back to sampling... but
    // gradients still pass through the Add graph-side, so the only
    // remaining sampling would come from unresolved maps. Verify the
    // bank itself shows the difference instead: b1_conv2's BP operand
    // resolves with the full capture and its FP operand survives, while
    // the fc head loses its operand without post-Add footprints.
    let bank = ReplayBank::from_trace(&net, &trace).unwrap();
    let s0 = bank.step_maps(0);
    assert!(s0
        .task_maps("b1_conv2", agos::nn::Phase::Backward)
        .is_some_and(|m| m.operand.is_some()));
    assert!(s0
        .task_maps("fc", agos::nn::Phase::Forward)
        .is_some_and(|m| m.operand.is_some()));
    let mut stripped = trace.clone();
    for s in &mut stripped.steps {
        s.layers.retain(|l| !l.name.ends_with("_add"));
    }
    let old = ReplayBank::from_trace(&net, &stripped).unwrap();
    let old_fc = old.step_maps(0).task_maps("fc", agos::nn::Phase::Forward);
    assert!(
        old_fc.is_none() || old_fc.unwrap().operand.is_none(),
        "without post-Add footprints the head's derivation stops at the Add"
    );
}

#[test]
fn cache_keys_separate_formats_and_fingerprints_fold_the_encoding() {
    let net = zoo::agos_resnet();
    let model = SparsityModel::synthetic(4);
    let cfg = AcceleratorConfig::default();
    let capture = capture_synthetic_trace(&net, &model, 1, BitmapPattern::Iid, 2);
    let v2 = TraceFile { format: TraceFormat::V2, ..capture.clone() };
    let v3 = TraceFile { format: TraceFormat::V3, ..capture };
    assert_ne!(v2.fingerprint(), v3.fingerprint(), "format folds into the fingerprint");

    let opts_for = |t: &TraceFile| SimOptions {
        batch: 2,
        trace_fingerprint: Some(t.fingerprint()),
        replay: Some(std::sync::Arc::new(ReplayBank::from_trace(&net, t).unwrap())),
        ..SimOptions::default()
    };
    let k2 = SweepKey::new(&net, Scheme::InOut, &cfg, &opts_for(&v2), &model);
    let k3 = SweepKey::new(&net, Scheme::InOut, &cfg, &opts_for(&v3), &model);
    assert_ne!(k2, k3, "v2 and v3 runs of the same content must not alias in the cache");
}

#[test]
fn corrupt_and_truncated_v3_files_error_with_context_and_degrade_leniently() {
    let dir = std::env::temp_dir().join("agos_trace_v3_corrupt");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("bad.json");

    // A v3 file whose second payload token stream is truncated
    // (covers 1 of 2 words) and whose delta has no previous step.
    let bad = r#"{
      "version": 3,
      "network": "fixture_net",
      "steps": [
        {"step": 0, "loss": 2.0, "layers": [
          {"name": "relu1", "act_sparsity": 0.5, "grad_sparsity": 0.5,
           "identity_ok": true,
           "act_bitmap": {"shape": [2, 6, 6], "enc": "rle", "words": "z1"}},
          {"name": "relu2", "act_sparsity": 0.5, "grad_sparsity": 0.5,
           "identity_ok": true,
           "grad_bitmap": {"shape": [1, 4, 4], "enc": "delta", "words": "z1"}}
        ]}
      ]
    }"#;
    std::fs::write(&path, bad).unwrap();
    // Strict: the first bad payload is a hard error naming its site.
    let err = TraceFile::load(&path).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("step 0"), "{msg}");
    assert!(msg.contains("relu1"), "{msg}");
    assert!(msg.contains("act_bitmap"), "{msg}");
    // Lenient: both payloads drop, each with its own contexted warning;
    // the scalar content survives.
    let (lenient, warnings) = TraceFile::load_lenient(&path).unwrap();
    assert_eq!(warnings.len(), 2, "{warnings:?}");
    assert!(warnings[0].contains("relu1") && warnings[0].contains("act_bitmap"));
    assert!(warnings[1].contains("relu2") && warnings[1].contains("delta"));
    assert!(!lenient.has_bitmaps());
    assert_eq!(lenient.steps[0].layers.len(), 2);

    // Structural damage is a hard error even leniently.
    std::fs::write(&path, r#"{"version": 3, "network": "x"}"#).unwrap();
    assert!(TraceFile::load_lenient(&path).is_err());
    // Unknown encodings are rejected, not guessed at.
    let unknown = bad.replace("\"rle\"", "\"lz4\"");
    std::fs::write(&path, unknown).unwrap();
    let err = format!("{:#}", TraceFile::load(&path).unwrap_err());
    assert!(err.contains("lz4"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn multi_image_capture_widens_the_replay_round_robin() {
    let net = zoo::agos_resnet();
    let model = SparsityModel::synthetic(3);
    let wide = capture_synthetic_trace_images(&net, &model, 2, 4, BitmapPattern::Iid, 2);
    assert_eq!(wide.steps.len(), 8, "steps x images trace steps");
    let bank = ReplayBank::from_trace(&net, &wide).unwrap();
    assert_eq!(bank.steps(), 8);
    // The round-robin wraps at steps x images, and distinct images get
    // distinct maps.
    assert!(std::ptr::eq(bank.step_maps(0), bank.step_maps(8)));
    assert!(!std::ptr::eq(bank.step_maps(0), bank.step_maps(1)));
    // Image 0 reproduces the narrow capture exactly.
    let narrow = capture_synthetic_trace(&net, &model, 2, BitmapPattern::Iid, 2);
    assert_eq!(narrow.steps[0], wide.steps[0]);
    assert_eq!(narrow.steps[1], wide.steps[4]);
}
