//! Integration: full simulator runs over all five evaluated networks,
//! checking the paper's headline shapes end-to-end (DESIGN.md §4).

use agos::config::{AcceleratorConfig, Scheme, SimOptions};
use agos::nn::{zoo, Phase};
use agos::sim::simulate_network;
use agos::sparsity::SparsityModel;

fn opts() -> SimOptions {
    SimOptions { batch: 4, ..SimOptions::default() }
}

#[test]
fn all_networks_all_schemes_complete_and_order() {
    let cfg = AcceleratorConfig::default();
    let model = SparsityModel::synthetic(0xBEEF);
    for net in zoo::all_networks() {
        let mut prev = f64::MAX;
        for scheme in Scheme::ALL {
            let r = simulate_network(&net, &cfg, &opts(), &model, scheme);
            let total = r.total_cycles();
            assert!(total.is_finite() && total > 0.0, "{} {}", net.name, scheme.label());
            assert!(
                total <= prev * 1.005,
                "{}: {} ({total:.0}) regressed vs previous scheme ({prev:.0})",
                net.name,
                scheme.label()
            );
            prev = total;
        }
    }
}

#[test]
fn paper_fig15_headline_speedups() {
    // Paper Fig 15 end-to-end speedups: VGG≈2.0, GoogLeNet≈2.18,
    // MobileNet≈2.13, DenseNet≈1.7, ResNet≈1.66. We require the same
    // *shape*: all in [1.3, 3.2], BN-free nets (vgg/googlenet) at least
    // matching the BN nets.
    let cfg = AcceleratorConfig::default();
    let model = SparsityModel::synthetic(2021);
    let mut speedups = std::collections::BTreeMap::new();
    for net in zoo::all_networks() {
        let dc = simulate_network(&net, &cfg, &opts(), &model, Scheme::Dense);
        let wr = simulate_network(&net, &cfg, &opts(), &model, Scheme::InOutWr);
        speedups.insert(net.name.clone(), dc.total_cycles() / wr.total_cycles());
    }
    for (net, s) in &speedups {
        assert!((1.25..3.4).contains(s), "{net}: overall speedup {s:.2}");
    }
    let bn_free_mean = (speedups["vgg16"] * speedups["googlenet"]).sqrt();
    let bn_mean = (speedups["resnet18"] * speedups["densenet121"]).sqrt();
    assert!(
        bn_free_mean > bn_mean * 0.95,
        "BN-free nets should benefit at least as much: {bn_free_mean:.2} vs {bn_mean:.2}"
    );
}

#[test]
fn paper_bp_speedup_band() {
    // Paper: BP speedups range 1.69–5.43x across the five networks.
    let cfg = AcceleratorConfig::default();
    let model = SparsityModel::synthetic(77);
    for net in zoo::all_networks() {
        let dc = simulate_network(&net, &cfg, &opts(), &model, Scheme::Dense);
        let wr = simulate_network(&net, &cfg, &opts(), &model, Scheme::InOutWr);
        let bp = dc.phase(Phase::Backward).cycles / wr.phase(Phase::Backward).cycles;
        assert!((1.3..6.5).contains(&bp), "{}: BP speedup {bp:.2}", net.name);
    }
}

#[test]
fn vgg_post_pool_layers_lose_output_sparsity() {
    // Fig 11a: convs directly after MaxPool (conv2_1, conv3_1, conv4_1,
    // conv5_1) get no OUT gain — IN+OUT ≈ IN for them.
    let cfg = AcceleratorConfig::default();
    let model = SparsityModel::synthetic(7);
    let net = zoo::vgg16();
    let inp = simulate_network(&net, &cfg, &opts(), &model, Scheme::In);
    let both = simulate_network(&net, &cfg, &opts(), &model, Scheme::InOut);
    for name in ["conv2_1", "conv3_1", "conv4_1", "conv5_1"] {
        let a = inp.layer(name, Phase::Backward).unwrap().cycles;
        let b = both.layer(name, Phase::Backward).unwrap().cycles;
        assert!((a / b - 1.0).abs() < 0.05, "{name}: IN {a:.0} vs IN+OUT {b:.0}");
    }
    // while a mid-block conv does gain
    let a = inp.layer("conv3_2", Phase::Backward).unwrap().cycles;
    let b = both.layer("conv3_2", Phase::Backward).unwrap().cycles;
    assert!(a / b > 1.25, "conv3_2 should gain from OUT: {:.2}", a / b);
}

#[test]
fn googlenet_inception_3b_range_matches_paper() {
    // Paper: inception-3b gains 2.6–12.6x (BP, layer-wise, all schemes).
    let cfg = AcceleratorConfig::default();
    let model = SparsityModel::synthetic(3);
    let net = zoo::googlenet();
    let dc = simulate_network(&net, &cfg, &opts(), &model, Scheme::Dense);
    let wr = simulate_network(&net, &cfg, &opts(), &model, Scheme::InOutWr);
    let mut min = f64::MAX;
    let mut max: f64 = 0.0;
    for l in &dc.per_layer {
        if l.phase != Phase::Backward || !l.name.starts_with("inception_3b") {
            continue;
        }
        let s = l.cycles / wr.layer(&l.name, Phase::Backward).unwrap().cycles;
        min = min.min(s);
        max = max.max(s);
    }
    assert!(min >= 1.0, "min {min:.2}");
    assert!(max <= 14.0, "max {max:.2}");
    assert!(max / min > 1.5, "expect a spread across layer types");
}

#[test]
fn energy_efficiency_improves_with_sparsity_on_all_networks() {
    let cfg = AcceleratorConfig::default();
    let model = SparsityModel::synthetic(123);
    for net in zoo::all_networks() {
        let dc = simulate_network(&net, &cfg, &opts(), &model, Scheme::Dense);
        let wr = simulate_network(&net, &cfg, &opts(), &model, Scheme::InOutWr);
        assert!(
            wr.total_energy_j() < dc.total_energy_j(),
            "{}: energy did not improve",
            net.name
        );
    }
}

#[test]
fn results_are_deterministic_given_seed() {
    let cfg = AcceleratorConfig::default();
    let model = SparsityModel::synthetic(5);
    let net = zoo::resnet18();
    let a = simulate_network(&net, &cfg, &opts(), &model, Scheme::InOutWr);
    let b = simulate_network(&net, &cfg, &opts(), &model, Scheme::InOutWr);
    assert_eq!(a.total_cycles(), b.total_cycles());
    assert_eq!(a.total_energy_j(), b.total_energy_j());
}

#[test]
fn scaling_the_pe_grid_scales_throughput() {
    // Doubling the grid should cut cycles roughly in half (ablation on
    // the design point).
    let model = SparsityModel::synthetic(9);
    let net = zoo::resnet18();
    let small = AcceleratorConfig { tx: 8, ty: 8, ..AcceleratorConfig::default() };
    let big = AcceleratorConfig::default(); // 16x16
    let rs = simulate_network(&net, &small, &opts(), &model, Scheme::Dense);
    let rb = simulate_network(&net, &big, &opts(), &model, Scheme::Dense);
    let ratio = rs.total_cycles() / rb.total_cycles();
    assert!((2.0..4.5).contains(&ratio), "8x8 vs 16x16 ratio {ratio:.2}");
}
