//! End-to-end co-simulation: real training through the PJRT artifacts →
//! real sparsity traces → accelerator simulation. The full three-layer
//! composition, in miniature (the `train_cnn` example does the long run).
//!
//! Skips when artifacts have not been built.

use std::path::PathBuf;

use agos::config::{AcceleratorConfig, ExecBackend, SimOptions, TrainOptions};
use agos::coordinator::{cosim_from_traces, run_training_pipeline, Trainer};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn short_training_run_learns_and_traces() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let opts = TrainOptions {
        steps: 8,
        trace_every: 4,
        log_every: 2,
        artifacts_dir: dir,
        ..TrainOptions::default()
    };
    let mut trainer = Trainer::new(opts).unwrap();
    let log = trainer.run().unwrap();
    assert!(!log.losses.is_empty());
    assert_eq!(log.traces.steps.len(), 2); // steps 0 and 4
    assert!(log.traces.identity_holds(), "identity must hold on real traces");
    for step in &log.traces.steps {
        assert_eq!(step.layers.len(), 4);
        for l in &step.layers {
            assert!(
                (0.05..0.95).contains(&l.act_sparsity),
                "{}: activation sparsity {}",
                l.name,
                l.act_sparsity
            );
            assert!(
                l.grad_sparsity >= l.act_sparsity - 1e-9,
                "{}: gradient can only be more sparse",
                l.name
            );
        }
    }
}

#[test]
fn pipeline_matches_trainer_and_feeds_cosim() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let opts = TrainOptions {
        steps: 6,
        trace_every: 3,
        log_every: 3,
        artifacts_dir: dir,
        ..TrainOptions::default()
    };
    let log = run_training_pipeline(&opts).unwrap();
    assert!(!log.traces.steps.is_empty());
    assert!(log.traces.identity_holds());

    // Real captures carry v2 bitmap payloads (image 0 per traced step).
    assert!(log.traces.has_bitmaps(), "trainer must capture packed bitmaps");

    // Feed the real traces straight into the simulator.
    let report = cosim_from_traces(
        &log.traces,
        &AcceleratorConfig::default(),
        &SimOptions { batch: 4, ..SimOptions::default() },
        false,
        0,
    )
    .unwrap();
    assert_eq!(report.network, "agos_cnn");
    assert!(
        report.bp_speedup > 1.2,
        "measured sparsity must yield BP speedup, got {:.2}",
        report.bp_speedup
    );
    assert!(report.total_speedup > 1.05, "total {:.2}", report.total_speedup);

    // Pattern-exact replay of the same real captures through the exact
    // backend — the full bitmap-native loop on genuine training data.
    let replayed = cosim_from_traces(
        &log.traces,
        &AcceleratorConfig::default(),
        &SimOptions {
            batch: 2,
            backend: ExecBackend::Exact,
            exact_outputs_per_tile: 16,
            ..SimOptions::default()
        },
        true,
        0,
    )
    .unwrap();
    assert!(replayed.replayed);
    assert!(replayed.bp_speedup > 1.1, "replayed BP {:.2}", replayed.bp_speedup);
}
