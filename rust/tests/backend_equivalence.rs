//! Backend equivalence contract (ISSUE 2 acceptance):
//!
//! * **Tracking**: the exact bitmap backend's mean cycles must track the
//!   analytic model within 20% at the *engine* level (whole networks,
//!   all schemes) — the aggregated closure of the per-output
//!   `analytic_model_tracks_exact_simulation` unit check. Networks here
//!   use receptive fields inside the analytic model's validated range
//!   (CRS ≥ ~64; see `sim::exact`'s validation grid).
//! * **Determinism**: exact results are bit-identical at any `--jobs`
//!   level, including the sweep runner's per-image fan-out path.

use agos::config::{AcceleratorConfig, ExecBackend, Scheme, SimOptions};
use agos::nn::{zoo, Network};
use agos::sim::{simulate_network, simulate_network_jobs, SweepPlan, SweepRunner};
use agos::sparsity::SparsityModel;

/// Small conv/ReLU stack with paper-scale receptive fields (3×3 kernels
/// over `chans` channel widths at an 8×8 map).
fn conv_stack(name: &str, c0: usize, chans: &[usize]) -> Network {
    let mut net = Network::new(name);
    let mut x = net.input(c0, 8, 8);
    for (i, &m) in chans.iter().enumerate() {
        let c = net.conv(&format!("conv{}", i + 1), x, m, 3, 1, 1);
        x = net.relu(&format!("relu{}", i + 1), c);
    }
    net.softmax("prob", x);
    net
}

fn exact_opts() -> SimOptions {
    SimOptions {
        batch: 2,
        backend: ExecBackend::Exact,
        // Small per-tile sample keeps the debug-mode walk fast; the
        // aggregate over hundreds of tiles still pins the mean tightly.
        exact_outputs_per_tile: 8,
        ..SimOptions::default()
    }
}

#[test]
fn exact_engine_tracks_analytic_within_20_percent() {
    let cfg = AcceleratorConfig::default();
    // CRS 288/576 (conv), 576 (BP), 64 (WG) — the validated band.
    let nets = [conv_stack("eq_a", 32, &[64, 64]), conv_stack("eq_b", 64, &[32, 64])];
    for net in &nets {
        let model = SparsityModel::synthetic(11);
        for scheme in Scheme::ALL {
            let analytic_opts =
                SimOptions { backend: ExecBackend::Analytic, ..exact_opts() };
            let a = simulate_network(net, &cfg, &analytic_opts, &model, scheme);
            let e = simulate_network(net, &cfg, &exact_opts(), &model, scheme);
            let (at, et) = (a.total_cycles(), e.total_cycles());
            let err = (et - at).abs() / at;
            assert!(
                err < 0.20,
                "{} {}: exact {et:.0} vs analytic {at:.0} cycles ({:.1}% deviation)",
                net.name,
                scheme.label(),
                err * 100.0
            );
            // MAC accounting must agree too (it is exact in expectation
            // on both backends).
            let (am, em) = (a.phase(agos::nn::Phase::Backward), e.phase(agos::nn::Phase::Backward));
            if am.performed_macs > 0.0 {
                let mac_err = (em.performed_macs - am.performed_macs).abs() / am.performed_macs;
                assert!(
                    mac_err < 0.20,
                    "{} {}: BP macs deviate {:.1}%",
                    net.name,
                    scheme.label(),
                    mac_err * 100.0
                );
            }
        }
    }
}

#[test]
fn exact_backend_jobs_invariance_golden() {
    // One combo under the exact backend: a 4-thread runner must use the
    // per-image fan-out (plan smaller than jobs) and still reproduce the
    // sequential engine bit-for-bit.
    let cfg = AcceleratorConfig::default();
    let opts = SimOptions { batch: 3, ..exact_opts() };
    let model = SparsityModel::synthetic(opts.seed);
    let net = zoo::agos_cnn();

    let sequential = simulate_network(&net, &cfg, &opts, &model, Scheme::InOutWr);
    let fanned = simulate_network_jobs(&net, &cfg, &opts, &model, Scheme::InOutWr, 4);
    let plan =
        SweepPlan::grid(std::slice::from_ref(&net), &[Scheme::InOutWr], &cfg, &opts);
    let via_runner = SweepRunner::new(4).run(&plan, &model);

    for (label, got) in [("fanout", &fanned), ("runner", &via_runner[0])] {
        assert_eq!(sequential.total_cycles(), got.total_cycles(), "{label}");
        assert_eq!(sequential.total_energy_j(), got.total_energy_j(), "{label}");
        assert_eq!(sequential.per_layer.len(), got.per_layer.len());
        for (a, b) in sequential.per_layer.iter().zip(&got.per_layer) {
            assert_eq!(a.cycles, b.cycles, "{label}: {} {}", a.name, a.phase.label());
            assert_eq!(a.performed_macs, b.performed_macs, "{label}: {}", a.name);
            assert_eq!(a.tile_mean, b.tile_mean, "{label}: {}", a.name);
        }
    }
}

#[test]
fn exact_backend_smoke_through_sweep_runner() {
    // The CI smoke: a tiny all-scheme exact sweep produces ordered,
    // cached results (CI runs this test by name so the path can't rot).
    let cfg = AcceleratorConfig::default();
    let opts = SimOptions { batch: 1, ..exact_opts() };
    let model = SparsityModel::synthetic(opts.seed);
    let runner = SweepRunner::new(2);
    let plan =
        SweepPlan::grid(&[zoo::agos_cnn()], &Scheme::ALL, &cfg, &opts);
    let results = runner.run(&plan, &model);
    assert_eq!(results.len(), 4);
    let dc = results[0].total_cycles();
    let wr = results[3].total_cycles();
    assert!(dc > wr, "exact sweep must show sparse speedup: DC {dc} vs WR {wr}");
    assert_eq!(runner.cache().misses(), 4);
    // Served from cache on repeat.
    let again = runner.run(&plan, &model);
    assert_eq!(runner.cache().misses(), 4);
    assert_eq!(again[0].total_cycles(), dc);
}
