//! Scenario expansion end to end: the committed example files parse and
//! run, expansion is bit-identical across `--jobs` levels, adversarial
//! patterns exercise both backends, and scenario fingerprints keep
//! sweep-cache keys disjoint from hand-written grids.

use std::path::Path;

use agos::config::{AcceleratorConfig, ExecBackend, Scheme, SimOptions};
use agos::nn::zoo;
use agos::scenario::{scenario_report_json, ScenarioFile};
use agos::sim::{SweepKey, SweepPlan, SweepRunner};
use agos::sparsity::SparsityModel;
use agos::util::json::Json;

fn example(name: &str) -> ScenarioFile {
    ScenarioFile::load(Path::new(&format!("examples/scenarios/{name}.json")))
        .unwrap_or_else(|e| panic!("{name}: {e}"))
}

#[test]
fn committed_examples_parse_expand_and_roundtrip() {
    // Every file referenced from the docs and CI must parse under the
    // strict parser, expand to a non-empty plan, and canonicalize to a
    // stable fingerprint.
    for name in ["trajectory_small", "generated_families", "adversarial_suite"] {
        let scn = example(name);
        let points = scn.points().unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(!points.is_empty(), "{name} expands to points");
        let again = ScenarioFile::from_json(&scn.to_json()).unwrap();
        assert_eq!(scn, again, "{name}: canonical roundtrip is the identity");
        assert_eq!(scn.fingerprint(), again.fingerprint(), "{name}");
    }
}

#[test]
fn trajectory_small_is_bit_identical_across_jobs_levels() {
    // The expansion golden: the CI smoke diffs `agos sweep --scenario`
    // outputs at --jobs 1 vs 4; this is the same contract in-process.
    let scn = example("trajectory_small");
    let cfg = AcceleratorConfig::default();
    let opts = SimOptions { batch: 1, ..SimOptions::default() };
    let ex = scn.expand(&cfg, &opts).unwrap();
    assert_eq!(ex.points.len(), 6, "2 networks x 3 phases");
    assert_eq!(ex.schemes.len(), 3);
    assert_eq!(ex.plan.len(), 18);
    assert_eq!(ex.opts.seed, 2109, "the file's seed wins");
    assert_eq!(ex.points[0].label, "agos_cnn@early");
    assert_eq!(ex.points[5].label, "ladder_d2_w8_k3_s1@late");

    let r1 = ex.run(&SweepRunner::new(1));
    let r4 = ex.run(&SweepRunner::new(4));
    let a = scenario_report_json(&ex, &r1).dump();
    let b = scenario_report_json(&ex, &r4).dump();
    assert_eq!(a, b, "jobs must not change the scenario report");
    assert!(a.contains("\"trajectory\""));

    // The point of the trajectory: speedup over DC grows with the
    // phase's sparsity scale (0.55 -> 1.0 -> 1.35 for agos_cnn).
    let speedup = |pi: usize| {
        let dc = r1[pi * 3].total_cycles();
        dc / r1[pi * 3 + 2].total_cycles()
    };
    assert!(speedup(1) >= speedup(0), "mid >= early");
    assert!(speedup(2) >= speedup(1), "late >= mid");
    assert!(speedup(2) > speedup(0), "late beats early outright");
}

#[test]
fn adversarial_patterns_run_both_backends_and_are_distinct() {
    let scn = ScenarioFile::from_json(
        &Json::parse(
            r#"{"version": 1, "seed": 5,
                "generators": [{"kind": "adversarial", "network": "agos_cnn"}],
                "schemes": "dc,in+out+wr"}"#,
        )
        .unwrap(),
    )
    .unwrap();
    let cfg = AcceleratorConfig::default();
    for backend in [ExecBackend::Analytic, ExecBackend::Exact] {
        let mut opts = SimOptions { batch: 1, ..SimOptions::default() };
        opts.backend = backend;
        opts.exact_outputs_per_tile = 8;
        let ex = scn.expand(&cfg, &opts).unwrap();
        assert_eq!(ex.points.len(), 3, "one point per pattern");
        let results = ex.run(&SweepRunner::new(2));
        let again = ex.run(&SweepRunner::new(1));
        assert_eq!(
            scenario_report_json(&ex, &results).dump(),
            scenario_report_json(&ex, &again).dump(),
            "{backend:?}: replayed patterns are deterministic"
        );
        // Point order follows AdversarialPattern::ALL: all_dense,
        // checkerboard, channel_collapsed. Under the sparse scheme the
        // half-empty patterns must beat the dense one, and the pattern
        // *structure* (not just density) must reach the result.
        let sparse = |pi: usize| results[pi * 2 + 1].total_cycles();
        assert!(
            sparse(0) > sparse(1),
            "{backend:?}: checkerboard (half density) must outrun all_dense"
        );
        assert!(
            sparse(0) > sparse(2),
            "{backend:?}: channel_collapsed must outrun all_dense"
        );
    }
}

#[test]
fn scenario_fingerprints_separate_cache_keys() {
    let cfg = AcceleratorConfig::default();
    let opts = SimOptions { batch: 1, ..SimOptions::default() };
    let model = SparsityModel::synthetic(opts.seed);
    let net = zoo::by_name("agos_cnn").unwrap();

    // Key level: the stamp (and its value) folds into the fingerprint.
    let key = |o: &SimOptions| SweepKey::new(&net, Scheme::Dense, &cfg, o, &model);
    let mut stamped = opts.clone();
    stamped.scenario_fingerprint = Some(0xFEED);
    let mut other = opts.clone();
    other.scenario_fingerprint = Some(0xBEEF);
    assert_ne!(key(&opts).fingerprint, key(&stamped).fingerprint);
    assert_ne!(key(&stamped).fingerprint, key(&other).fingerprint);

    // Runner level: a scenario whose grid nominally overlaps a plain
    // sweep (same network, schemes, seed, batch, identity scale) never
    // poaches its cache entries — and re-running the scenario hits.
    let scn = ScenarioFile::from_json(
        &Json::parse(
            r#"{"version": 1,
                "generators": [{"kind": "zoo", "networks": "agos_cnn"}],
                "schemes": "dc,in+out+wr"}"#,
        )
        .unwrap(),
    )
    .unwrap();
    assert_eq!(scn.seed, opts.seed, "default seeds line up for the overlap");
    let runner = SweepRunner::new(2);
    let schemes = [Scheme::Dense, Scheme::InOutWr];
    let plan = SweepPlan::grid(&[net.clone()], &schemes, &cfg, &opts);
    runner.run(&plan, &model);
    assert_eq!(runner.cache().misses(), 2);

    let ex = scn.expand(&cfg, &opts).unwrap();
    let results = ex.run(&runner);
    assert_eq!(results.len(), 2);
    assert_eq!(
        runner.cache().misses(),
        4,
        "scenario combos must not alias the plain grid's cache entries"
    );
    ex.run(&runner);
    assert_eq!(runner.cache().misses(), 4, "re-running the scenario is pure cache hits");
    assert!(runner.cache().hits() >= 2);
}
