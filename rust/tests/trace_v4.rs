//! Trace container v4 conformance suite (ISSUE 7 acceptance):
//!
//! * **Fixture golden**: the committed `tests/data/trace_v4.bin` (a
//!   hand-assembled container exercising raw, RLE and delta payloads)
//!   decodes to pinned content and re-encodes byte-identically — the
//!   on-disk grammar cannot drift silently.
//! * **Codec**: the binary word-level RLE round-trips bit-identically
//!   property-style (all-zero, all-ones, iid, blobbed, checkerboard),
//!   and whole containers round-trip through `save`/`load` including
//!   multi-step delta chains and multi-image step groups (where the
//!   image-aligned tag-3 delta base must both round-trip and pay).
//! * **Streaming**: `TraceWriter` appending one step at a time produces
//!   the same bytes as the whole-file encode — the bounded-memory
//!   capture path writes the identical container.
//! * **Size**: a v4 container is never larger than the v3 JSON of the
//!   same capture (binary tokens vs text grammar).
//! * **Robustness**: a stream truncated mid-step errors strictly with
//!   the step record named, and recovers every complete step (with a
//!   warning) on the lenient path `agos cosim` uses.
//!
//! The v4 == v3 replay-cosim golden on both backends lives in
//! `trace_v3.rs` (`v3_replay_equals_v2_replay_cosim_golden` loops every
//! `TraceFormat`), so encoding equivalence is pinned in one place.

use std::path::{Path, PathBuf};

use agos::nn::Shape;
use agos::sparsity::{rle_decode_words_bin, rle_encode_words_bin, Bitmap};
use agos::trace::{LayerTrace, StepTrace, TraceFile, TraceFormat, TraceWriter};
use agos::util::rng::Pcg32;

fn fixture(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/data").join(name)
}

/// The content `tests/data/trace_v4.bin` was hand-assembled to carry.
fn fixture_content() -> TraceFile {
    let shape = Shape::new(1, 1, 64);
    let act = Bitmap::from_words(shape, vec![0xDEAD_BEEF]).unwrap();
    TraceFile {
        network: "agos_cnn".into(),
        format: TraceFormat::V4,
        steps: vec![
            StepTrace {
                step: 0,
                loss: 2.5,
                layers: vec![LayerTrace::from_bitmaps(
                    "relu1",
                    act.clone(),
                    Bitmap::zeros(shape),
                )],
            },
            StepTrace {
                step: 1,
                loss: 1.25,
                layers: vec![LayerTrace::from_bitmaps("relu1", act, Bitmap::ones(shape))],
            },
        ],
    }
}

#[test]
fn fixture_golden_decodes_and_reencodes_byte_identically() {
    let path = fixture("trace_v4.bin");
    let t = TraceFile::load(&path).unwrap();
    assert_eq!(t.format, TraceFormat::V4);
    assert_eq!(t, fixture_content(), "pinned decode of the committed container");
    // Scalars derived from the payloads, as `from_bitmaps` guarantees.
    assert!((t.steps[0].layers[0].act_sparsity - 0.625).abs() < 1e-12);
    assert!((t.steps[0].layers[0].grad_sparsity - 1.0).abs() < 1e-12);
    assert!(t.steps[0].layers[0].identity_ok, "zero grad is contained in anything");
    assert!(!t.steps[1].layers[0].identity_ok, "all-ones grad violates the identity");
    // Re-encoding reproduces the fixture bytes exactly: raw for the
    // mid-density word, RLE for the runs, delta for the repeated act map.
    let dir = std::env::temp_dir().join("agos_trace_v4_golden");
    std::fs::create_dir_all(&dir).unwrap();
    let out = dir.join("resaved.trace.bin");
    t.save(&out).unwrap();
    assert_eq!(
        std::fs::read(&out).unwrap(),
        std::fs::read(&path).unwrap(),
        "re-encode must be byte-identical to the committed fixture"
    );
    // The lenient path agrees on an undamaged file.
    let (lenient, warnings) = TraceFile::load_lenient(&path).unwrap();
    assert!(warnings.is_empty(), "{warnings:?}");
    assert_eq!(lenient, t);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn truncated_stream_errors_strictly_and_recovers_complete_steps_leniently() {
    let bytes = std::fs::read(fixture("trace_v4.bin")).unwrap();
    let dir = std::env::temp_dir().join("agos_trace_v4_trunc");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cut.trace.bin");
    // Cut mid-way through step record 1 (the fixture's second step).
    std::fs::write(&path, &bytes[..bytes.len() - 10]).unwrap();
    let err = format!("{:#}", TraceFile::load(&path).unwrap_err());
    assert!(err.contains("step record 1"), "{err}");
    let (t, warnings) = TraceFile::load_lenient(&path).unwrap();
    assert_eq!(t.steps.len(), 1, "every complete step survives");
    assert_eq!(t.steps[0], fixture_content().steps[0]);
    assert_eq!(warnings.len(), 1, "{warnings:?}");
    assert!(warnings[0].contains("1 complete steps"), "{warnings:?}");
    // A damaged header is a hard error in both modes.
    std::fs::write(&path, &bytes[..12]).unwrap();
    assert!(TraceFile::load(&path).is_err());
    assert!(TraceFile::load_lenient(&path).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

/// Pattern corpus for codec property tests: degenerate, stochastic and
/// the RLE-adversarial alternating checkerboard.
fn pattern_corpus(shape: Shape, rng: &mut Pcg32) -> Vec<Bitmap> {
    let mut checker = Bitmap::zeros(shape);
    for c in 0..shape.c {
        for y in 0..shape.h {
            for x in 0..shape.w {
                if (c + y + x) % 2 == 0 {
                    checker.set(c, y, x, true);
                }
            }
        }
    }
    vec![
        Bitmap::zeros(shape),
        Bitmap::ones(shape),
        Bitmap::sample(shape, 0.03, rng),
        Bitmap::sample(shape, 0.5, rng),
        Bitmap::sample_blobs(shape, 0.05, 4, rng),
        checker,
    ]
}

#[test]
fn binary_rle_codec_roundtrips_property_style() {
    let mut rng = Pcg32::new(0xB14A);
    for shape in [Shape::new(16, 32, 32), Shape::new(3, 7, 9), Shape::new(1, 1, 1)] {
        for b in pattern_corpus(shape, &mut rng) {
            let mut enc = Vec::new();
            rle_encode_words_bin(b.words(), shape.len(), &mut enc);
            let words = rle_decode_words_bin(&enc, shape.len()).unwrap();
            assert_eq!(words, b.words(), "shape {shape}");
            // The Bitmap-level wrappers agree.
            let mut enc2 = Vec::new();
            b.encode_rle_bin(&mut enc2);
            assert_eq!(enc, enc2);
            assert_eq!(Bitmap::decode_rle_bin(shape, &enc2).unwrap(), b);
        }
    }
}

#[test]
fn containers_roundtrip_through_save_and_load_with_delta_chains() {
    let dir = std::env::temp_dir().join("agos_trace_v4_roundtrip");
    std::fs::create_dir_all(&dir).unwrap();
    let shape = Shape::new(8, 16, 16);
    let mut rng = Pcg32::new(0x44);
    // Three steps whose maps drift slightly — the correlated capture the
    // delta encoding exists for — plus every corpus pattern as its own
    // step so degenerate payloads ride the same chain.
    let mut steps = Vec::new();
    let mut act = Bitmap::sample_blobs(shape, 0.06, 3, &mut rng);
    for step in 0..3usize {
        let keep = Bitmap::sample(shape, 0.5, &mut rng);
        let grad = act.and(&keep);
        steps.push(StepTrace {
            step,
            loss: 2.0 - step as f64 * 0.25,
            layers: vec![LayerTrace::from_bitmaps("relu1", act.clone(), grad)],
        });
        let flip = Bitmap::sample(shape, 0.01, &mut rng);
        act = act.xor(&flip);
    }
    for (i, b) in pattern_corpus(shape, &mut rng).into_iter().enumerate() {
        let grad = Bitmap::zeros(shape);
        steps.push(StepTrace {
            step: 3 + i,
            loss: 1.0,
            layers: vec![LayerTrace::from_bitmaps("relu1", b, grad)],
        });
    }
    let t = TraceFile {
        network: "agos_cnn".into(),
        format: TraceFormat::V4,
        steps,
    };
    let path = dir.join("chain.trace.bin");
    t.save(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    assert_eq!(&bytes[..8], b"AGOSTRC\0", "v4 containers lead with the magic");
    assert_eq!(TraceFile::load(&path).unwrap(), t, "bit-exact container round-trip");
    // The streaming writer produces the identical container.
    let stream_path = dir.join("streamed.trace.bin");
    let mut w = TraceWriter::create(&stream_path, &t.network).unwrap();
    for s in &t.steps {
        w.append(s).unwrap();
    }
    assert_eq!(w.finish().unwrap(), t.steps.len());
    assert_eq!(
        std::fs::read(&stream_path).unwrap(),
        bytes,
        "streamed == whole-file encode"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn multi_image_groups_roundtrip_and_compress_with_image_aligned_deltas() {
    let dir = std::env::temp_dir().join("agos_trace_v4_groups");
    std::fs::create_dir_all(&dir).unwrap();
    let shape = Shape::new(8, 16, 16);
    let mut rng = Pcg32::new(0x66);
    // Four images drifting independently over three steps, captured
    // step-major: the records of one step share its step value — the
    // group shape `agos train --trace-images` writes.
    let mut imgs: Vec<Bitmap> =
        (0..4).map(|_| Bitmap::sample_blobs(shape, 0.06, 3, &mut rng)).collect();
    let mut steps = Vec::new();
    for step in 0..3usize {
        for act in &imgs {
            let grad = act.and(&Bitmap::sample(shape, 0.5, &mut rng));
            steps.push(StepTrace {
                step,
                loss: 2.0 - step as f64 * 0.25,
                layers: vec![LayerTrace::from_bitmaps("relu1", act.clone(), grad)],
            });
        }
        for act in &mut imgs {
            let flip = Bitmap::sample(shape, 0.01, &mut rng);
            *act = act.xor(&flip);
        }
    }
    let t = TraceFile { network: "agos_cnn".into(), format: TraceFormat::V4, steps };
    let path = dir.join("groups.trace.bin");
    t.save(&path).unwrap();
    assert_eq!(TraceFile::load(&path).unwrap(), t, "bit-exact group round-trip");
    // The streaming writer produces the identical container — its
    // group-rotation bookkeeping must match the whole-file encoder's.
    let stream_path = dir.join("groups-streamed.trace.bin");
    let mut w = TraceWriter::create(&stream_path, &t.network).unwrap();
    for s in &t.steps {
        w.append(s).unwrap();
    }
    assert_eq!(w.finish().unwrap(), t.steps.len());
    assert_eq!(std::fs::read(&stream_path).unwrap(), std::fs::read(&path).unwrap());
    // Relabeling the records with distinct step values destroys the
    // groups: each map's only delta base becomes the (uncorrelated)
    // neighboring image. The image-aligned base must pay for itself.
    let flat = TraceFile {
        steps: t
            .steps
            .iter()
            .enumerate()
            .map(|(i, s)| StepTrace { step: i, ..s.clone() })
            .collect(),
        ..t.clone()
    };
    let flat_path = dir.join("flat.trace.bin");
    flat.save(&flat_path).unwrap();
    let (grouped, ungrouped) = (
        std::fs::metadata(&path).unwrap().len(),
        std::fs::metadata(&flat_path).unwrap().len(),
    );
    assert!(
        grouped < ungrouped,
        "grouped capture ({grouped} bytes) must encode smaller than its ungrouped \
relabeling ({ungrouped} bytes)"
    );
    assert_eq!(TraceFile::load(&flat_path).unwrap(), flat);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn v4_container_is_never_larger_than_v3_json() {
    let dir = std::env::temp_dir().join("agos_trace_v4_size");
    std::fs::create_dir_all(&dir).unwrap();
    let shape = Shape::new(32, 32, 32);
    let mut rng = Pcg32::new(0x51);
    for density in [0.02, 0.3, 0.7] {
        let act = Bitmap::sample_blobs(shape, density, 4, &mut rng);
        let keep = Bitmap::sample(shape, 0.5, &mut rng);
        let grad = act.and(&keep);
        let mk = |format: TraceFormat| TraceFile {
            network: "size_bench".into(),
            format,
            steps: vec![
                StepTrace {
                    step: 0,
                    loss: 2.0,
                    layers: vec![LayerTrace::from_bitmaps("relu1", act.clone(), grad.clone())],
                },
                StepTrace {
                    step: 1,
                    loss: 1.9,
                    layers: vec![LayerTrace::from_bitmaps("relu1", act.clone(), grad.clone())],
                },
            ],
        };
        let p3 = dir.join("t.v3.json");
        let p4 = dir.join("t.v4.bin");
        mk(TraceFormat::V3).save(&p3).unwrap();
        mk(TraceFormat::V4).save(&p4).unwrap();
        let (s3, s4) = (
            std::fs::metadata(&p3).unwrap().len(),
            std::fs::metadata(&p4).unwrap().len(),
        );
        assert!(s4 <= s3, "density {density}: v4 {s4} bytes > v3 {s3} bytes");
        // And the two decode to the same content.
        assert_eq!(TraceFile::load(&p4).unwrap().steps, TraceFile::load(&p3).unwrap().steps);
    }
    std::fs::remove_dir_all(&dir).ok();
}
