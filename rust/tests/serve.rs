//! `agos serve` end-to-end (ISSUE 8 acceptance): a real server on a real
//! Unix socket, driven through the client library.
//!
//! * **Byte identity**: a served `cosim` / `sweep` result is
//!   byte-identical to the file the cold CLI writes with `--out` for the
//!   same request — the determinism contract extended to the service.
//! * **One computation**: duplicate requests — concurrent (in-flight
//!   dedup) or sequential (resident sweep cache) — never re-simulate:
//!   the resident cache's miss counter stays at one grid's worth.
//! * **Lifecycle**: a live socket refuses a second server, a stale
//!   socket file is reclaimed, and `shutdown` stops the serve loop and
//!   removes the socket.

#![cfg(unix)]

use std::path::PathBuf;
use std::time::Duration;

use agos::config::BitmapPattern;
use agos::nn::zoo;
use agos::serve::{Client, ServeOptions, Server};
use agos::sparsity::{capture_synthetic_trace, SparsityModel};
use agos::util::json::Json;

fn sv(xs: &[&str]) -> Vec<String> {
    xs.iter().map(|s| s.to_string()).collect()
}

/// A per-test scratch dir (pid-qualified so parallel `cargo test`
/// processes never collide on the socket path).
fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("agos_serve_{name}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn start(socket: &PathBuf) -> (Server, ServeOptions) {
    let opts = ServeOptions {
        socket: socket.clone(),
        jobs: 1,
        workers: 4,
        cache_path: None,
    };
    (Server::bind(opts.clone()).unwrap(), opts)
}

#[test]
fn served_results_match_cold_cli_byte_for_byte_and_share_work() {
    let dir = scratch("e2e");
    let traces = dir.join("traces.trace.bin");
    capture_synthetic_trace(
        &zoo::agos_cnn(),
        &SparsityModel::synthetic(0xA605),
        2,
        BitmapPattern::Blobs,
        2,
    )
    .save(&traces)
    .unwrap();

    // Cold baselines, written by the ordinary CLI in this process.
    let cold_cosim = dir.join("cold-cosim.json");
    let cold_sweep = dir.join("cold-sweep.json");
    let tr = traces.to_str().unwrap();
    assert_eq!(
        agos::cli::run(&sv(&[
            "cosim", "--traces", tr, "--replay", "--backend", "exact", "--batch", "2",
            "--exact-cap", "16", "--jobs", "2", "--out", cold_cosim.to_str().unwrap(),
        ]))
        .unwrap(),
        0
    );
    assert_eq!(
        agos::cli::run(&sv(&[
            "sweep", "--networks", "agos_cnn", "--schemes", "dc,in+out+wr", "--batch", "1",
            "--jobs", "2", "--cache", "none", "--out", cold_sweep.to_str().unwrap(),
        ]))
        .unwrap(),
        0
    );
    let cold_cosim = std::fs::read_to_string(&cold_cosim).unwrap();
    let cold_sweep = std::fs::read_to_string(&cold_sweep).unwrap();

    let socket = dir.join("agos.sock");
    let (server, _) = start(&socket);
    let state = server.state();
    let handle = std::thread::spawn(move || server.run());

    let req = Json::parse(&format!(
        r#"{{"cmd":"cosim","traces":"{tr}","replay":true,"backend":"exact","batch":2,"exact_cap":16}}"#
    ))
    .unwrap();

    // First contact: a concurrent duplicate pair, each on its own
    // connection. Whether they overlap (in-flight dedup) or not (sweep
    // cache), both must get the cold CLI's exact bytes.
    let (a, b) = {
        let spawn_one = |req: Json, socket: PathBuf| {
            std::thread::spawn(move || {
                let mut c = Client::connect_retry(&socket, Duration::from_secs(10)).unwrap();
                c.request(&req).unwrap()
            })
        };
        let ta = spawn_one(req.clone(), socket.clone());
        let tb = spawn_one(req.clone(), socket.clone());
        (ta.join().unwrap(), tb.join().unwrap())
    };
    assert_eq!(a.pretty(), cold_cosim, "served cosim == cold `--out` bytes");
    assert_eq!(b.pretty(), cold_cosim, "both duplicates get identical bytes");

    // One four-scheme grid was simulated, total, for both requests.
    assert_eq!(state.sweep_cache().misses(), 4, "duplicates must share one computation");

    let mut client = Client::connect(&socket).unwrap();

    // Sequential repeat: resident warm state answers without simulating.
    assert_eq!(client.request(&req).unwrap().pretty(), cold_cosim);
    assert_eq!(state.sweep_cache().misses(), 4, "warm repeat must not re-simulate");

    // Served sweep, same byte-identity contract.
    let sweep_req = Json::parse(
        r#"{"cmd":"sweep","networks":"agos_cnn","schemes":"dc,in+out+wr","batch":1}"#,
    )
    .unwrap();
    assert_eq!(client.request(&sweep_req).unwrap().pretty(), cold_sweep);

    // Ping reports the resident state; the trace bank is warm.
    let ping = client.request(&Json::parse(r#"{"cmd":"ping"}"#).unwrap()).unwrap();
    assert_eq!(ping.get("sim_rev").as_u64(), Some(6));
    let banks = match ping.get("banks") {
        Json::Arr(rows) => rows.clone(),
        other => panic!("banks must be an array, got {}", other.dump()),
    };
    assert_eq!(banks.len(), 1, "one trace file stays resident");
    assert_eq!(banks[0].get("network").as_str(), Some("agos_cnn"));
    assert!(banks[0].get("replay_words").as_u64().unwrap() > 0);

    // A bad request errors in-band and the session survives it.
    let err = client.request(&Json::parse(r#"{"cmd":"nonsense"}"#).unwrap()).unwrap_err();
    assert!(format!("{err:#}").contains("unknown cmd"), "{err:#}");
    assert_eq!(client.request(&req).unwrap().pretty(), cold_cosim);

    // While the server lives, its socket refuses a second bind.
    let second = Server::bind(ServeOptions {
        socket: socket.clone(),
        jobs: 1,
        workers: 1,
        cache_path: None,
    });
    let msg = format!("{:#}", second.err().expect("live socket must refuse a second server"));
    assert!(msg.contains("live server"), "{msg}");

    let bye = client.request(&Json::parse(r#"{"cmd":"shutdown"}"#).unwrap()).unwrap();
    assert_eq!(bye.get("shutting_down").as_bool(), Some(true));
    handle.join().unwrap().unwrap();
    assert!(!socket.exists(), "shutdown must remove the socket file");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn stale_socket_file_is_reclaimed_on_bind() {
    let dir = scratch("stale");
    let socket = dir.join("stale.sock");
    // A leftover path nothing listens on — the crashed-server case.
    std::fs::write(&socket, b"").unwrap();
    let (server, _) = start(&socket);
    let state = server.state();
    let handle = std::thread::spawn(move || server.run());
    let mut client = Client::connect_retry(&socket, Duration::from_secs(10)).unwrap();
    let ping = client.request(&Json::parse(r#"{"cmd":"ping"}"#).unwrap()).unwrap();
    assert_eq!(ping.get("service").as_str(), Some("agos"));
    assert_eq!(ping.get("jobs").as_u64(), Some(state.jobs() as u64));
    client.request(&Json::parse(r#"{"cmd":"shutdown"}"#).unwrap()).unwrap();
    handle.join().unwrap().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}
