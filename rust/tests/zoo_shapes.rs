//! Cross-checks on the model zoo: parameter counts, receptive fields and
//! the structural properties the sparsity analysis depends on.

use agos::nn::{layer_macs, network_macs, zoo, LayerKind, Phase};
use agos::sparsity::{analyze_network, SparsityKind, SparsityModel};

#[test]
fn parameter_counts_match_literature() {
    // (network, conv+fc parameter count range in millions)
    let expect = [
        ("vgg16", 130.0, 140.0),     // 138M
        ("resnet18", 11.0, 12.2),    // 11.7M
        ("googlenet", 5.5, 7.2),     // ~6.6M (main branch)
        ("densenet121", 6.5, 8.5),   // ~8.0M
        ("mobilenet_v1", 3.8, 4.6),  // 4.2M
    ];
    for (name, lo, hi) in expect {
        let net = zoo::by_name(name).unwrap();
        let mut params = 0u64;
        for l in net.compute_layers() {
            let cin = net.layer(l.inputs[0]).out.c;
            params += match l.kind {
                LayerKind::Conv { m, r, s, .. } => (m * cin * r * s + m) as u64,
                LayerKind::DwConv { r, s, .. } => (cin * r * s + cin) as u64,
                LayerKind::Fc { out } => {
                    let flat = net.layer(l.inputs[0]).out.len();
                    (out * flat + out) as u64
                }
                _ => 0,
            };
        }
        let m = params as f64 / 1e6;
        assert!((lo..hi).contains(&m), "{name}: {m:.2}M params");
    }
}

#[test]
fn bp_macs_equal_fp_macs_per_network() {
    for net in zoo::all_networks() {
        let fp = network_macs(&net, Phase::Forward);
        let bp = network_macs(&net, Phase::Backward);
        let wg = network_macs(&net, Phase::WeightGrad);
        assert_eq!(wg, fp, "{}", net.name);
        // BP = FP minus the first compute layer
        let first = net.compute_layers()[0];
        assert_eq!(bp, fp - layer_macs(&net, first, Phase::Forward), "{}", net.name);
    }
}

#[test]
fn receptive_field_spread_exercises_blocking_and_reconfig() {
    // The design handles CRS < 1024 (reconfig) and > 1024 (blocking);
    // the zoo must exercise both regimes.
    let mut small = 0;
    let mut large = 0;
    for net in zoo::all_networks() {
        for l in net.compute_layers() {
            let crs = l.receptive_field(net.layer(l.inputs[0]).out).unwrap();
            if crs < 1024 {
                small += 1;
            }
            if crs > 1024 {
                large += 1;
            }
        }
    }
    assert!(small > 40, "small-CRS layers: {small}");
    assert!(large > 40, "large-CRS layers: {large}");
}

#[test]
fn bn_structure_drives_bp_kind() {
    let model = SparsityModel::synthetic(1);

    // VGG / GoogLeNet (no BN): inner convs get Both.
    for name in ["vgg16", "googlenet"] {
        let net = zoo::by_name(name).unwrap();
        let fwd = model.assign(&net);
        let opps = analyze_network(&net, &fwd);
        let both = opps.iter().filter(|o| o.bp_kind() == SparsityKind::Both).count();
        assert!(both >= 5, "{name}: only {both} layers with Both");
    }

    // ResNet / DenseNet / MobileNet (BN): no conv sees BP input sparsity
    // from a directly-following ReLU — the figure the paper stresses.
    for name in ["resnet18", "densenet121", "mobilenet_v1"] {
        let net = zoo::by_name(name).unwrap();
        let fwd = model.assign(&net);
        let opps = analyze_network(&net, &fwd);
        let out_only = opps.iter().filter(|o| o.bp_kind() == SparsityKind::OutputOnly).count();
        let with_in = opps.iter().filter(|o| o.bp_input.is_some()).count();
        assert!(out_only >= 5, "{name}: only {out_only} OutputOnly layers");
        assert_eq!(with_in, 0, "{name}: BN must kill all BP input sparsity");
    }
}

#[test]
fn densenet_concat_keeps_output_sparsity_everywhere() {
    let net = zoo::densenet121();
    let model = SparsityModel::synthetic(4);
    let fwd = model.assign(&net);
    let opps = analyze_network(&net, &fwd);
    for o in &opps {
        if o.name == "conv0" || o.name == "fc" {
            continue;
        }
        assert!(o.bp_output.is_some(), "{}: OUT lost", o.name);
    }
}

#[test]
fn googlenet_pool_proj_convs_lose_output_sparsity() {
    // Inception pool-branch convs read from MaxPool ⇒ no OUT (the paper's
    // bar-6 observation).
    let net = zoo::googlenet();
    let model = SparsityModel::synthetic(4);
    let fwd = model.assign(&net);
    let opps = analyze_network(&net, &fwd);
    for o in &opps {
        if o.name.ends_with("_pool_proj") {
            assert!(o.bp_output.is_none(), "{}: OUT should be lost", o.name);
        }
        if o.name.ends_with("_3x3") && o.name.contains("inception") {
            assert!(o.bp_output.is_some(), "{}: OUT should hold", o.name);
        }
    }
}
