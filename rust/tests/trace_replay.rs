//! Bitmap-native trace pipeline contract (ISSUE 3 acceptance):
//!
//! * **Format**: v2 trace files round-trip their packed payloads
//!   bit-exactly through disk; v1 files (no version key, no payloads)
//!   still load.
//! * **Equivalence**: replaying captured patterns at a given density
//!   tracks the sampled exact backend at the matched density within a
//!   tolerance — replay changes *patterns*, not the workload.
//! * **Determinism**: the replay path is bit-identical at any `--jobs`
//!   level, including the sweep runner's per-image fan-out (replayed
//!   slices draw no RNG at all, so this is even stronger than the
//!   sampled contract).
//! * **Cache soundness**: two traces with identical per-layer means but
//!   different patterns can never share a sweep-cache entry.

use std::sync::Arc;

use agos::config::{AcceleratorConfig, BitmapPattern, ExecBackend, GatherMode, Scheme, SimOptions};
use agos::nn::{zoo, Phase, Shape};
use agos::sim::{
    exact_tile_cost, simulate_network, simulate_network_jobs, BitmapSource, ExactPe, ReplayBank,
    SweepKey, SweepPlan, SweepRunner, TaskGeom, TileGeom,
};
use agos::sparsity::{capture_synthetic_trace, Bitmap, SparsityModel};
use agos::trace::TraceFile;
use agos::util::json::Json;
use agos::util::rng::Pcg32;

fn exact_opts(batch: usize) -> SimOptions {
    SimOptions {
        batch,
        backend: ExecBackend::Exact,
        // Small per-tile sample keeps the debug-mode walk fast; the
        // aggregate over hundreds of tiles still pins the mean tightly.
        exact_outputs_per_tile: 16,
        ..SimOptions::default()
    }
}

fn replay_opts(batch: usize, trace: &TraceFile, bank: ReplayBank) -> SimOptions {
    SimOptions {
        trace_fingerprint: Some(trace.fingerprint()),
        replay: Some(Arc::new(bank)),
        ..exact_opts(batch)
    }
}

#[test]
fn v2_trace_file_roundtrips_payloads_through_disk() {
    let net = zoo::agos_cnn();
    let model = SparsityModel::synthetic(9);
    let trace = capture_synthetic_trace(&net, &model, 2, BitmapPattern::Blobs, 2);
    assert!(trace.has_bitmaps());

    let dir = std::env::temp_dir().join("agos_trace_replay_roundtrip");
    let path = dir.join("v2.json");
    trace.save(&path).unwrap();
    let loaded = TraceFile::load(&path).unwrap();
    assert_eq!(trace, loaded, "payloads must survive disk bit-exactly");
    assert_eq!(trace.fingerprint(), loaded.fingerprint());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn v1_trace_file_still_loads() {
    let dir = std::env::temp_dir().join("agos_trace_replay_v1");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("v1.json");
    // Byte-for-byte what the pre-payload pipeline wrote: no version key,
    // scalar layer entries only.
    std::fs::write(
        &path,
        r#"{
  "network": "agos_cnn",
  "steps": [
    {"step": 0, "loss": 2.1, "layers": [
      {"name": "relu1", "act_sparsity": 0.5, "grad_sparsity": 0.55, "identity_ok": true},
      {"name": "relu2", "act_sparsity": 0.4, "grad_sparsity": 0.4, "identity_ok": true}
    ]}
  ]
}"#,
    )
    .unwrap();
    let t = TraceFile::load(&path).unwrap();
    assert_eq!(t.network, "agos_cnn");
    assert_eq!(t.steps[0].layers.len(), 2);
    assert!(!t.has_bitmaps());
    assert!(t.identity_holds());
    // And a v1 load re-saves as v2 without inventing payloads.
    let resaved = TraceFile::from_json(&Json::parse(&t.to_json().pretty()).unwrap()).unwrap();
    assert_eq!(t, resaved);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn replayed_tracks_sampled_at_matched_density() {
    // The capture's patterns are drawn at exactly the densities the
    // model assigns, so replaying them must land near the sampled exact
    // backend — pattern-exactness changes the *variance structure*, not
    // the workload.
    let cfg = AcceleratorConfig::default();
    let net = zoo::agos_cnn();
    let model = SparsityModel::synthetic(11);
    let sampled_o = exact_opts(2);
    let trace = capture_synthetic_trace(&net, &model, 2, BitmapPattern::Iid, 2);
    let bank = ReplayBank::from_trace(&net, &trace).unwrap();
    let replay_o = replay_opts(2, &trace, bank);
    for scheme in [Scheme::In, Scheme::InOut, Scheme::InOutWr] {
        let s = simulate_network(&net, &cfg, &sampled_o, &model, scheme);
        let r = simulate_network(&net, &cfg, &replay_o, &model, scheme);
        let (st, rt) = (s.total_cycles(), r.total_cycles());
        let err = (rt - st).abs() / st;
        assert!(
            err < 0.25,
            "{}: replayed {rt:.0} vs sampled {st:.0} cycles ({:.1}% deviation)",
            scheme.label(),
            err * 100.0
        );
        let (sm, rm) = (
            s.phase(agos::nn::Phase::Backward).performed_macs,
            r.phase(agos::nn::Phase::Backward).performed_macs,
        );
        let mac_err = (rm - sm).abs() / sm;
        assert!(
            mac_err < 0.25,
            "{}: BP macs deviate {:.1}%",
            scheme.label(),
            mac_err * 100.0
        );
    }
}

#[test]
fn replay_jobs_invariance_golden() {
    // One combo under replay: the 4-thread runner must use the per-image
    // fan-out (plan smaller than jobs) and still reproduce the
    // sequential engine bit-for-bit.
    let cfg = AcceleratorConfig::default();
    let net = zoo::agos_cnn();
    let model = SparsityModel::synthetic(0xA605);
    let trace = capture_synthetic_trace(&net, &model, 3, BitmapPattern::Blobs, 2);
    let bank = ReplayBank::from_trace(&net, &trace).unwrap();
    let opts = replay_opts(5, &trace, bank);

    let sequential = simulate_network(&net, &cfg, &opts, &model, Scheme::InOutWr);
    let fanned = simulate_network_jobs(&net, &cfg, &opts, &model, Scheme::InOutWr, 4);
    let plan = SweepPlan::grid(std::slice::from_ref(&net), &[Scheme::InOutWr], &cfg, &opts);
    let via_runner = SweepRunner::new(4).run(&plan, &model);

    for (label, got) in [("fanout", &fanned), ("runner", &via_runner[0])] {
        assert_eq!(sequential.total_cycles(), got.total_cycles(), "{label}");
        assert_eq!(sequential.total_energy_j(), got.total_energy_j(), "{label}");
        assert_eq!(sequential.per_layer.len(), got.per_layer.len());
        for (a, b) in sequential.per_layer.iter().zip(&got.per_layer) {
            assert_eq!(a.cycles, b.cycles, "{label}: {} {}", a.name, a.phase.label());
            assert_eq!(a.performed_macs, b.performed_macs, "{label}: {}", a.name);
            assert_eq!(a.tile_mean, b.tile_mean, "{label}: {}", a.name);
        }
    }
}

#[test]
fn different_patterns_same_means_never_share_cache_entries() {
    // The SweepCache soundness gap this PR closes: same network, same
    // per-layer mean sparsities, different captured patterns — the keys
    // must differ, for both the replay handle and the bare trace
    // fingerprint (the non-replay cosim path).
    let cfg = AcceleratorConfig::default();
    let net = zoo::agos_cnn();
    let model = SparsityModel::synthetic(4);
    // Same model, same densities; only the drawn patterns differ.
    let t_iid = capture_synthetic_trace(&net, &model, 2, BitmapPattern::Iid, 2);
    let t_blob = capture_synthetic_trace(&net, &model, 2, BitmapPattern::Blobs, 2);
    assert_ne!(t_iid.fingerprint(), t_blob.fingerprint());

    let o_iid = replay_opts(2, &t_iid, ReplayBank::from_trace(&net, &t_iid).unwrap());
    let o_blob = replay_opts(2, &t_blob, ReplayBank::from_trace(&net, &t_blob).unwrap());
    let k_iid = SweepKey::new(&net, Scheme::InOut, &cfg, &o_iid, &model);
    let k_blob = SweepKey::new(&net, Scheme::InOut, &cfg, &o_blob, &model);
    assert_ne!(k_iid, k_blob, "replayed traces must never alias in the cache");

    // Replayed vs sampled at the same everything-else must differ too.
    let k_sampled = SweepKey::new(&net, Scheme::InOut, &cfg, &exact_opts(2), &model);
    assert_ne!(k_iid, k_sampled);

    // And the fingerprint-only path (no replay handle, e.g. analytic
    // cosim of two different trace files) separates as well.
    let f_a = SimOptions { trace_fingerprint: Some(t_iid.fingerprint()), ..exact_opts(2) };
    let f_b = SimOptions { trace_fingerprint: Some(t_blob.fingerprint()), ..exact_opts(2) };
    assert_ne!(
        SweepKey::new(&net, Scheme::InOut, &cfg, &f_a, &model),
        SweepKey::new(&net, Scheme::InOut, &cfg, &f_b, &model)
    );
}

#[test]
fn gather_equals_streaming_on_single_channel_1x1_stride1_convs() {
    // The one geometry where the two window assemblies must coincide
    // bit-for-bit: a single-channel 1×1 stride-1 pad-0 conv. The
    // geometry gather reads exactly the map bit at (0, y, x); the
    // streaming slice anchors at the identically-scaled flat position
    // y·w + x and takes crs = 1 bit — the same bit. Whole tiles must
    // therefore cost identically through both paths.
    let pe = ExactPe::default();
    let mut rng = Pcg32::new(3);
    let map = Bitmap::sample(Shape::new(1, 12, 12), 0.5, &mut rng);
    let geom = TileGeom { index: 0, m: 1, u: 12, v: 12, window: (0, 12, 0, 12) };
    let conv = TaskGeom::Conv { r: 1, s: 1, stride: 1, pad: 0, dw: false };
    let dense_out = BitmapSource::Sampled {
        density: 1.0,
        pattern: BitmapPattern::Iid,
        blob_radius: 0,
    };
    let gathered = exact_tile_cost(
        &pe,
        1,
        &geom,
        4096,
        &BitmapSource::Gathered { map: &map, geom: conv, runs: None },
        &dense_out,
        None,
        &mut Pcg32::new(1),
    );
    let streamed = exact_tile_cost(
        &pe,
        1,
        &geom,
        4096,
        &BitmapSource::Streamed { map: &map },
        &dense_out,
        None,
        &mut Pcg32::new(1),
    );
    assert_eq!(gathered, streamed, "1x1/s1/p0 single-channel windows must be bit-identical");
    assert_eq!(gathered.1, map.count_nz() as f64, "MACs are exactly the map popcount");
}

#[test]
fn wg_pair_replay_tracks_sampled_wg_at_matched_density() {
    // The WG phase replayed through joint act×grad pairs must land near
    // the sampled exact backend at the model's matched joint density —
    // the pair changes patterns, not the workload.
    let cfg = AcceleratorConfig::default();
    let net = zoo::agos_cnn();
    let model = SparsityModel::synthetic(21);
    let sampled_o = exact_opts(2);
    let trace = capture_synthetic_trace(&net, &model, 2, BitmapPattern::Iid, 2);
    let bank = ReplayBank::from_trace(&net, &trace).unwrap();
    let replay_o = replay_opts(2, &trace, bank);
    for scheme in [Scheme::In, Scheme::InOutWr] {
        let s = simulate_network(&net, &cfg, &sampled_o, &model, scheme);
        let r = simulate_network(&net, &cfg, &replay_o, &model, scheme);
        let (sw, rw) = (s.phase(Phase::WeightGrad), r.phase(Phase::WeightGrad));
        let cyc_err = (rw.cycles - sw.cycles).abs() / sw.cycles;
        let mac_err = (rw.performed_macs - sw.performed_macs).abs() / sw.performed_macs;
        assert!(
            cyc_err < 0.30,
            "{}: WG pair {:.0} vs sampled {:.0} cycles ({:.1}%)",
            scheme.label(),
            rw.cycles,
            sw.cycles,
            cyc_err * 100.0
        );
        assert!(mac_err < 0.30, "{}: WG macs deviate {:.1}%", scheme.label(), mac_err * 100.0);
    }
}

#[test]
fn replayed_cosim_draws_zero_rng_in_all_three_phases() {
    // The acceptance bar: with geometry-exact replay armed, every task
    // of every phase (FP operand gathers, BP operand/mask, WG pairs,
    // pool/GAP-derived FC operands) resolves from captured maps — so
    // the engine's per-image RNG streams are never touched, and changing
    // the stream seed cannot change any result, on either backend.
    let cfg = AcceleratorConfig::default();
    let net = zoo::agos_cnn();
    let model = SparsityModel::synthetic(11);
    let trace = capture_synthetic_trace(&net, &model, 2, BitmapPattern::Blobs, 2);
    for backend in [ExecBackend::Exact, ExecBackend::Analytic] {
        let mk = |seed: u64| SimOptions {
            seed,
            backend,
            ..replay_opts(3, &trace, ReplayBank::from_trace(&net, &trace).unwrap())
        };
        for scheme in Scheme::ALL {
            let a = simulate_network(&net, &cfg, &mk(1), &model, scheme);
            let b = simulate_network(&net, &cfg, &mk(0xDEAD_BEEF), &model, scheme);
            assert_eq!(
                a.total_cycles(),
                b.total_cycles(),
                "{backend:?}/{}: replay must be seed-independent (zero RNG)",
                scheme.label()
            );
            assert_eq!(a.total_energy_j(), b.total_energy_j());
            for (x, y) in a.per_layer.iter().zip(&b.per_layer) {
                assert_eq!(x.cycles, y.cycles, "{backend:?} {} {}", x.name, x.phase.label());
                assert_eq!(x.performed_macs, y.performed_macs);
            }
        }
    }
    // The streaming legacy mode, by contrast, still samples WG — seeds
    // must matter there (the contrast proves the test has teeth).
    let stream = |seed: u64| SimOptions {
        seed,
        gather: GatherMode::Streaming,
        ..replay_opts(3, &trace, ReplayBank::from_trace(&net, &trace).unwrap())
    };
    let a = simulate_network(&net, &cfg, &stream(1), &model, Scheme::InOutWr);
    let b = simulate_network(&net, &cfg, &stream(0xDEAD_BEEF), &model, Scheme::InOutWr);
    assert_ne!(a.total_cycles(), b.total_cycles(), "streaming WG still samples");
}

#[test]
fn analytic_replay_agrees_with_exact_replay_on_validated_crs_stacks() {
    // The pattern-informed analytic fast path must track the exact
    // replay within the same kind of tolerance the sampled backends
    // hold to (backend_equivalence) — agos_cnn's receptive fields all
    // sit in the PE-validated CRS range.
    let cfg = AcceleratorConfig::default();
    let net = zoo::agos_cnn();
    let model = SparsityModel::synthetic(31);
    let trace = capture_synthetic_trace(&net, &model, 2, BitmapPattern::Iid, 2);
    for scheme in [Scheme::Dense, Scheme::In, Scheme::InOut, Scheme::InOutWr] {
        let exact_o = replay_opts(2, &trace, ReplayBank::from_trace(&net, &trace).unwrap());
        let analytic_o = SimOptions {
            backend: ExecBackend::Analytic,
            ..replay_opts(2, &trace, ReplayBank::from_trace(&net, &trace).unwrap())
        };
        let e = simulate_network(&net, &cfg, &exact_o, &model, scheme);
        let a = simulate_network(&net, &cfg, &analytic_o, &model, scheme);
        let err = (a.total_cycles() - e.total_cycles()).abs() / e.total_cycles();
        assert!(
            err < 0.30,
            "{}: analytic-replay {:.0} vs exact-replay {:.0} cycles ({:.1}%)",
            scheme.label(),
            a.total_cycles(),
            e.total_cycles(),
            err * 100.0
        );
    }
}

#[test]
fn blob_pattern_flows_through_the_engine() {
    // `--pattern blobs` must change results (clustered lane imbalance)
    // while keeping MAC accounting at the same density.
    let cfg = AcceleratorConfig::default();
    let net = zoo::agos_cnn();
    let model = SparsityModel::synthetic(6);
    let iid = exact_opts(1);
    let blobs = SimOptions { pattern: BitmapPattern::Blobs, blob_radius: 4, ..exact_opts(1) };
    let a = simulate_network(&net, &cfg, &iid, &model, Scheme::InOutWr);
    let b = simulate_network(&net, &cfg, &blobs, &model, Scheme::InOutWr);
    assert_ne!(a.total_cycles(), b.total_cycles(), "pattern must reach the PE walk");
    let (am, bm) = (
        a.phase(agos::nn::Phase::Backward).performed_macs,
        b.phase(agos::nn::Phase::Backward).performed_macs,
    );
    let mac_err = (bm - am).abs() / am;
    assert!(mac_err < 0.2, "density preserved across patterns ({mac_err:.3})");
}
