//! Integration: rust PJRT runtime executes the AOT artifacts end-to-end.
//!
//! Skips (passes trivially) when `artifacts/` has not been built — run
//! `make artifacts` first for the real coverage.

use std::path::PathBuf;

use agos::runtime::{HostTensor, Runtime};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn gemm_demo_runs_and_multiplies() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut rt = Runtime::load(&dir).unwrap();
    // a = I scaled by 2, b = ones ⇒ a @ b = 2·ones
    let n = 64;
    let mut a = vec![0f32; n * n];
    for i in 0..n {
        a[i * n + i] = 2.0;
    }
    let b = vec![1f32; n * n];
    let out = rt
        .run(
            "gemm_demo",
            &[
                HostTensor::f32(vec![n, n], a).unwrap(),
                HostTensor::f32(vec![n, n], b).unwrap(),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 1);
    let y = out[0].as_f32().unwrap();
    assert_eq!(out[0].shape(), &[n, n]);
    assert!(y.iter().all(|v| (*v - 2.0).abs() < 1e-5));
}

#[test]
fn run_validates_inputs_against_manifest() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut rt = Runtime::load(&dir).unwrap();
    // wrong arity
    assert!(rt.run("gemm_demo", &[]).is_err());
    // wrong shape
    let bad = HostTensor::zeros_f32(vec![2, 2]);
    assert!(rt.run("gemm_demo", &[bad.clone(), bad]).is_err());
    // unknown entry
    assert!(rt.run("not_an_entry", &[]).is_err());
}

#[test]
fn train_step_reduces_loss_and_updates_params() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut rt = Runtime::load(&dir).unwrap();
    let mut params = rt.manifest.load_initial_params().unwrap();
    let spec = rt.manifest.entry("train_step").unwrap().clone();
    let batch = rt.manifest.batch;
    let img = rt.manifest.img;
    let in_ch = rt.manifest.in_ch;
    let classes = rt.manifest.num_classes;

    // Deterministic synthetic batch.
    let mut rng = agos::util::rng::Pcg32::new(1234);
    let x: Vec<f32> = (0..batch * img * img * in_ch)
        .map(|_| rng.gauss() as f32)
        .collect();
    let labels: Vec<i32> = (0..batch).map(|_| rng.below(classes as u32) as i32).collect();
    let x = HostTensor::f32(vec![batch, img, img, in_ch], x).unwrap();
    let y = HostTensor::i32(vec![batch], labels).unwrap();

    let n_params = params.len();
    assert_eq!(spec.inputs.len(), n_params + 2);

    let mut losses = Vec::new();
    for _ in 0..4 {
        let mut inputs = params.clone();
        inputs.push(x.clone());
        inputs.push(y.clone());
        let out = rt.run("train_step", &inputs).unwrap();
        assert_eq!(out.len(), n_params + 1);
        let loss = out[n_params].as_f32().unwrap()[0];
        assert!(loss.is_finite());
        losses.push(loss);
        params = out[..n_params].to_vec();
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss did not decrease on repeated batch: {losses:?}"
    );
}

#[test]
fn step_traces_exposes_sparsity_identity() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut rt = Runtime::load(&dir).unwrap();
    let params = rt.manifest.load_initial_params().unwrap();
    let batch = rt.manifest.batch;
    let img = rt.manifest.img;
    let in_ch = rt.manifest.in_ch;

    let mut rng = agos::util::rng::Pcg32::new(99);
    let x: Vec<f32> = (0..batch * img * img * in_ch)
        .map(|_| rng.gauss() as f32)
        .collect();
    let labels: Vec<i32> =
        (0..batch).map(|_| rng.below(rt.manifest.num_classes as u32) as i32).collect();

    let mut inputs = params;
    inputs.push(HostTensor::f32(vec![batch, img, img, in_ch], x).unwrap());
    inputs.push(HostTensor::i32(vec![batch], labels).unwrap());
    let out = rt.run("step_traces", &inputs).unwrap();
    assert_eq!(out.len(), 9);

    // outputs: loss, a1..a4, g1..g4
    for i in 1..=4 {
        let a = out[i].as_f32().unwrap();
        let g = out[i + 4].as_f32().unwrap();
        assert_eq!(out[i].shape(), out[i + 4].shape());
        // Paper §3.2: activation zero ⇒ gradient zero, element-exact.
        for (av, gv) in a.iter().zip(g) {
            if *av == 0.0 {
                assert_eq!(*gv, 0.0, "gradient nonzero where activation is zero");
            }
        }
        let sa = out[i].zero_fraction();
        let sg = out[i + 4].zero_fraction();
        assert!(sg >= sa - 1e-9, "gradient can only be more sparse");
        assert!(sa > 0.15 && sa < 0.85, "layer {i} activation sparsity {sa:.3}");
    }
}
