//! The sweep layer's contract (ISSUE 1 acceptance):
//!
//! * **Golden**: a parallel four-scheme sweep of one network produces
//!   bit-identical `NetworkSimResult`s to the sequential engine.
//! * **Cache**: the same combo requested twice simulates exactly once.
//! * **Determinism**: results are independent of the `--jobs` level and
//!   of batch iteration order (per-image derived RNG streams).
//! * **Shared banks** (ISSUE 8): concurrent runners over one
//!   `Arc<ReplayBank>`, one `Arc<GatherPlanCache>` and one shared
//!   `Arc<SweepCache>` — the resident-service topology — produce
//!   bit-identical results to a sequential run with private state.

use std::collections::BTreeMap;
use std::sync::Arc;

use agos::config::{AcceleratorConfig, BitmapPattern, ExecBackend, Scheme, SimOptions};
use agos::nn::zoo;
use agos::sim::{
    build_image_tasks, image_stream, simulate_image, simulate_network, GatherPlanCache,
    NetworkSimResult, ReplayBank, SweepCache, SweepPlan, SweepRunner,
};
use agos::sparsity::{capture_synthetic_trace, SparsityModel};

fn assert_identical(a: &NetworkSimResult, b: &NetworkSimResult) {
    assert_eq!(a.network, b.network);
    assert_eq!(a.scheme, b.scheme);
    assert_eq!(a.total_cycles(), b.total_cycles(), "{} {}", a.network, a.scheme.label());
    assert_eq!(a.total_energy_j(), b.total_energy_j());
    for (pa, pb) in a.totals.values().zip(b.totals.values()) {
        assert_eq!(pa.cycles, pb.cycles);
        assert_eq!(pa.dense_macs, pb.dense_macs);
        assert_eq!(pa.performed_macs, pb.performed_macs);
    }
    assert_eq!(a.per_layer.len(), b.per_layer.len());
    for (la, lb) in a.per_layer.iter().zip(&b.per_layer) {
        assert_eq!(la.name, lb.name);
        assert_eq!(la.phase, lb.phase);
        assert_eq!(la.cycles, lb.cycles, "{} {}", la.name, la.phase.label());
        assert_eq!(la.performed_macs, lb.performed_macs, "{}", la.name);
        assert_eq!(la.tile_utilization, lb.tile_utilization, "{}", la.name);
    }
}

#[test]
fn golden_parallel_sweep_matches_sequential_engine() {
    let cfg = AcceleratorConfig::default();
    let opts = SimOptions { batch: 2, ..SimOptions::default() };
    let model = SparsityModel::synthetic(opts.seed);
    let net = zoo::vgg16();

    let runner = SweepRunner::new(4);
    let plan = SweepPlan::grid(std::slice::from_ref(&net), &Scheme::ALL, &cfg, &opts);
    let parallel = runner.run(&plan, &model);
    assert_eq!(parallel.len(), 4);

    for (scheme, got) in Scheme::ALL.into_iter().zip(&parallel) {
        let sequential = simulate_network(&net, &cfg, &opts, &model, scheme);
        assert_identical(got, &sequential);
    }
}

#[test]
fn same_combo_twice_simulates_once() {
    let cfg = AcceleratorConfig::default();
    let opts = SimOptions { batch: 1, ..SimOptions::default() };
    let model = SparsityModel::synthetic(opts.seed);
    let runner = SweepRunner::new(4);

    let mut plan = SweepPlan::new();
    plan.push(zoo::agos_cnn(), Scheme::InOutWr, &cfg, &opts);
    plan.push(zoo::agos_cnn(), Scheme::InOutWr, &cfg, &opts);
    let out = runner.run(&plan, &model);
    assert!(Arc::ptr_eq(&out[0], &out[1]), "one simulation must serve both requests");
    assert_eq!(runner.cache().misses(), 1, "exactly one fresh simulation");
    assert_eq!(runner.cache().hits(), 1);

    // `one()` after the plan is a pure cache hit as well.
    let again = runner.one(&zoo::agos_cnn(), &cfg, &opts, &model, Scheme::InOutWr);
    assert!(Arc::ptr_eq(&again, &out[0]));
    assert_eq!(runner.cache().misses(), 1);
}

#[test]
fn results_are_independent_of_jobs_level() {
    let cfg = AcceleratorConfig::default();
    let opts = SimOptions { batch: 1, ..SimOptions::default() };
    let model = SparsityModel::synthetic(0xBEEF);
    let nets = [zoo::agos_cnn(), zoo::resnet18()];
    let plan = SweepPlan::grid(&nets, &Scheme::ALL, &cfg, &opts);

    let serial = SweepRunner::new(1).run(&plan, &model);
    let threaded = SweepRunner::new(4).run(&plan, &model);
    assert_eq!(serial.len(), threaded.len());
    for (a, b) in serial.iter().zip(&threaded) {
        assert_identical(a, b);
    }
}

#[test]
fn concurrent_sweeps_over_shared_banks_match_sequential() {
    // The `agos serve` topology: every warm structure — replay bank,
    // gather-plan cache, sweep cache — is one shared immutable instance
    // behind an Arc, and two requests sweep through it at once.
    let cfg = AcceleratorConfig::default();
    let net = zoo::agos_cnn();
    let model = SparsityModel::synthetic(0xA605);
    let trace = capture_synthetic_trace(&net, &model, 2, BitmapPattern::Blobs, 2);
    let bank = Arc::new(ReplayBank::from_trace(&net, &trace).unwrap());
    let plans = Arc::new(GatherPlanCache::new());
    let opts = SimOptions {
        batch: 2,
        backend: ExecBackend::Exact,
        exact_outputs_per_tile: 8,
        trace_fingerprint: Some(trace.fingerprint()),
        replay: Some(bank.clone()),
        gather_plans: Some(plans.clone()),
        ..SimOptions::default()
    };
    let full = SweepPlan::grid(std::slice::from_ref(&net), &Scheme::ALL, &cfg, &opts);

    // Baseline: a sequential runner with private everything.
    let sequential = SweepRunner::new(1).run(&full, &model);

    // Two concurrent runners split the grid between them (disjoint keys,
    // so the miss count below is deterministic) and race through the
    // shared bank and plan cache at jobs=2 each.
    let cache = Arc::new(SweepCache::new());
    let halves = [
        SweepPlan::grid(std::slice::from_ref(&net), &Scheme::ALL[..2], &cfg, &opts),
        SweepPlan::grid(std::slice::from_ref(&net), &Scheme::ALL[2..], &cfg, &opts),
    ];
    let (a, b) = std::thread::scope(|scope| {
        let mut handles = halves.iter().map(|plan| {
            let cache = cache.clone();
            let model = &model;
            scope.spawn(move || SweepRunner::with_cache(2, cache).run(plan, model))
        });
        let (ta, tb) = (handles.next().unwrap(), handles.next().unwrap());
        (ta.join().unwrap(), tb.join().unwrap())
    });
    assert_eq!(cache.misses(), 4, "each combo simulated by exactly one runner");
    assert_eq!(cache.hits(), 0);

    let concurrent: Vec<_> = a.iter().chain(&b).collect();
    assert_eq!(sequential.len(), concurrent.len());
    for (s, c) in sequential.iter().zip(&concurrent) {
        assert_identical(s, c);
        // JSON form too: what a served response is built from.
        assert_eq!(s.to_json().dump(), c.to_json().dump(), "{}", s.scheme.label());
    }

    // A third runner over the same cache re-requests the full grid and
    // simulates nothing — the resident-service warm path.
    let warm = SweepRunner::with_cache(2, cache.clone()).run(&full, &model);
    assert_eq!(cache.misses(), 4, "warm re-request must not re-simulate");
    assert_eq!(cache.hits(), 4);
    for (s, w) in sequential.iter().zip(&warm) {
        assert_identical(s, w);
    }
}

#[test]
fn engine_totals_equal_independent_per_image_simulations() {
    // The decomposition the executor relies on: the batch engine is the
    // image-order fold of independent per-image simulations, each with
    // its own (seed, image)-derived stream.
    let cfg = AcceleratorConfig::default();
    let opts = SimOptions { batch: 4, ..SimOptions::default() };
    let model = SparsityModel::synthetic(21);
    let net = zoo::agos_cnn();
    let scheme = Scheme::InOutWr;
    let engine = simulate_network(&net, &cfg, &opts, &model, scheme);

    let batch = model.assign_batch(&net, opts.batch);
    let mut per_combo: BTreeMap<(String, &'static str), Vec<f64>> = BTreeMap::new();
    // Simulate images in reverse order: must not matter.
    for image in (0..batch.len()).rev() {
        let tasks = build_image_tasks(&net, &batch[image]);
        let mut rng = image_stream(opts.seed, image);
        let results = simulate_image(&tasks, &cfg, &opts, scheme, image, &mut rng);
        for (t, r) in tasks.iter().zip(&results) {
            let e = per_combo.entry((t.layer.clone(), t.phase.label())).or_default();
            // Keep image order inside each group for bit-equal folds.
            e.insert(0, r.cycles);
        }
    }
    for l in &engine.per_layer {
        let cycles: f64 = per_combo[&(l.name.clone(), l.phase.label())].iter().sum();
        assert_eq!(cycles, l.cycles, "{} {}", l.name, l.phase.label());
    }
}
