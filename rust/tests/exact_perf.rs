//! Exact-backend raw-speed contract (ISSUE 6 acceptance): gather plans,
//! chunked popcounts and RLE-aware zero-skip are *pure execution
//! strategy* — every optimized path must be bit-identical to the direct
//! path it replaces, on every pattern class and geometry, and the
//! end-to-end replayed cosim report must not change by a byte whether
//! the optimizations are on or off, at any `--jobs` level.

use std::sync::Arc;

use agos::config::{BitmapPattern, ExecBackend, SimOptions};
use agos::coordinator::cosim_from_traces;
use agos::nn::{zoo, Shape};
use agos::sim::{count_bits_range, GatherPlanCache, PlannedGather, SkipStats, TaskGeom};
use agos::sparsity::{capture_synthetic_trace, Bitmap, SparsityModel};
use agos::util::rng::Pcg32;

/// The five pattern classes the optimizations must be transparent on:
/// the extremes exercise the skip/short-circuit machinery, iid/blobs the
/// common case, the checkerboard defeats every run-based shortcut.
fn patterns(shape: Shape) -> Vec<(&'static str, Bitmap)> {
    let mut rng = Pcg32::new(0xE6);
    let mut checker = Bitmap::zeros(shape);
    for c in 0..shape.c {
        for y in 0..shape.h {
            for x in 0..shape.w {
                checker.set(c, y, x, (c + y + x) % 2 == 0);
            }
        }
    }
    vec![
        ("all-zero", Bitmap::zeros(shape)),
        ("all-ones", Bitmap::ones(shape)),
        ("iid", Bitmap::sample(shape, 0.45, &mut rng)),
        ("blobs", Bitmap::sample_blobs(shape, 0.12, 2, &mut rng)),
        ("checkerboard", checker),
    ]
}

/// The window anchor `(ay, ax, wh, ww)` a geometry reads for output
/// `(y, x)` — the same math the direct gather and the plan builder use
/// (`None`: a structurally empty ConvT window).
fn window(tg: TaskGeom, y: usize, x: usize) -> Option<(isize, isize, usize, usize)> {
    match tg {
        TaskGeom::Conv { r, s, stride, pad, .. } => Some((
            (y * stride) as isize - pad as isize,
            (x * stride) as isize - pad as isize,
            r,
            s,
        )),
        TaskGeom::ConvT { r, s, stride, pad, .. } => {
            let sd = stride.max(1) as isize;
            let (yp, xp) = ((y + pad) as isize, (x + pad) as isize);
            let u_min = (yp - r as isize).div_euclid(sd) + 1;
            let u_max = yp.div_euclid(sd);
            let v_min = (xp - s as isize).div_euclid(sd) + 1;
            let v_max = xp.div_euclid(sd);
            if u_max < u_min || v_max < v_min {
                return None;
            }
            Some((u_min, v_min, (u_max - u_min + 1) as usize, (v_max - v_min + 1) as usize))
        }
        TaskGeom::Full | TaskGeom::Streaming | TaskGeom::Wg { .. } => unreachable!(),
    }
}

fn dw(tg: TaskGeom) -> bool {
    match tg {
        TaskGeom::Conv { dw, .. } | TaskGeom::ConvT { dw, .. } => dw,
        _ => false,
    }
}

#[test]
fn planned_gather_equals_direct_gather_on_every_pattern_class() {
    let shape = Shape::new(3, 9, 10);
    let (u, v) = (8, 9);
    let geoms = [
        TaskGeom::Conv { r: 3, s: 3, stride: 1, pad: 1, dw: false },
        TaskGeom::Conv { r: 5, s: 5, stride: 2, pad: 2, dw: true },
        TaskGeom::ConvT { r: 3, s: 3, stride: 2, pad: 1, dw: false },
        TaskGeom::ConvT { r: 4, s: 4, stride: 2, pad: 0, dw: true },
    ];
    let cache = GatherPlanCache::new();
    for (label, map) in patterns(shape) {
        let runs = map.run_index();
        for tg in geoms {
            let plan = cache.plan_for(shape, tg, u, v).expect("windowed geoms plan");
            let mut stats = SkipStats::default();
            let (mut direct, mut planned) = (Vec::new(), Vec::new());
            for ch in 0..shape.c {
                for y in 0..u {
                    for x in 0..v {
                        let (c0, c1) = if dw(tg) { (ch, ch + 1) } else { (0, shape.c) };
                        let expect = match window(tg, y, x) {
                            Some((ay, ax, wh, ww)) => {
                                Some(map.gather_window_words(c0, c1, ay, ax, wh, ww, &mut direct))
                            }
                            None => None,
                        };
                        let got =
                            plan.gather(&map, Some(&runs), ch, y, x, &mut stats, &mut planned);
                        match (expect, got) {
                            (None, PlannedGather::Words { len }) => {
                                assert_eq!(len, 0, "{label} {tg:?} ({ch},{y},{x})");
                            }
                            (Some(n), PlannedGather::Words { len }) => {
                                assert_eq!(len, n, "{label} {tg:?} ({ch},{y},{x})");
                                assert_eq!(
                                    planned, direct,
                                    "{label} {tg:?} ({ch},{y},{x}): planned bits diverge"
                                );
                            }
                            (Some(n), PlannedGather::AllOnes { len }) => {
                                // The short-circuit may only claim dense
                                // when the direct gather *is* dense.
                                assert_eq!(len, n, "{label} {tg:?} ({ch},{y},{x})");
                                assert_eq!(
                                    count_bits_range(&direct, 0, n),
                                    n as u64,
                                    "{label} {tg:?} ({ch},{y},{x}): short-circuit on non-dense"
                                );
                            }
                            (None, PlannedGather::AllOnes { .. }) => {
                                panic!("{label} {tg:?}: empty window claimed dense")
                            }
                        }
                    }
                }
            }
            // On the all-ones map the padding-free interior must actually
            // take the short-circuit (the plan knows which windows are
            // structurally full).
            if label == "all-ones" {
                assert!(stats.windows_shortcircuited > 0, "{tg:?}");
            }
            if label == "all-zero" {
                assert!(stats.words_skipped > 0 && stats.words_gathered == 0, "{tg:?}");
            }
        }
    }
    // One plan per (geometry, plane) across all five patterns: the cache
    // key is pattern-free.
    assert_eq!(cache.len(), geoms.len());
    // Unwindowed geometries never plan — they keep their dedicated paths.
    for tg in [
        TaskGeom::Full,
        TaskGeom::Streaming,
        TaskGeom::Wg { r: 3, s: 3, stride: 1, pad: 1, gu: 4, gv: 4, dw: false },
    ] {
        assert!(cache.plan_for(shape, tg, u, v).is_none(), "{tg:?}");
    }
}

#[test]
fn chunked_popcount_matches_per_bit_reference() {
    let mut rng = Pcg32::new(0xBEEF);
    // Word streams covering the drain's edge cases: the 4-wide interior
    // chunks, their remainder, single-word ranges and 64-bit tails.
    let mut streams: Vec<Vec<u64>> = vec![
        vec![0; 8],
        vec![u64::MAX; 8],
        (0..8).map(|i| if i % 2 == 0 { 0xAAAA_AAAA_AAAA_AAAA } else { 0x5555_5555_5555_5555 }).collect(),
    ];
    let mut random = Vec::new();
    for _ in 0..8 {
        random.push(((rng.next_u32() as u64) << 32) | rng.next_u32() as u64);
    }
    streams.push(random);
    for words in &streams {
        let bits = words.len() * 64;
        for lo in [0, 1, 7, 63, 64, 65, 130] {
            for hi in [lo + 1, lo + 63, lo + 64, lo + 65, lo + 257, bits] {
                if hi <= lo || hi > bits {
                    continue;
                }
                let reference = (lo..hi)
                    .filter(|i| (words[i / 64] >> (i % 64)) & 1 == 1)
                    .count() as u64;
                assert_eq!(
                    count_bits_range(words, lo, hi),
                    reference,
                    "[{lo}, {hi}) of {} words",
                    words.len()
                );
            }
        }
    }
}

#[test]
fn replayed_cosim_is_byte_identical_with_plans_on_or_off_at_any_jobs() {
    let opts = SimOptions {
        batch: 2,
        backend: ExecBackend::Exact,
        exact_outputs_per_tile: 16,
        ..SimOptions::default()
    };
    let traces = capture_synthetic_trace(
        &zoo::agos_cnn(),
        &SparsityModel::synthetic(opts.seed),
        2,
        BitmapPattern::Blobs,
        2,
    );
    let cfg = agos::config::AcceleratorConfig::default();
    let full = Arc::new(GatherPlanCache::new());
    let variants: Vec<(&str, Option<Arc<GatherPlanCache>>)> = vec![
        ("plans off", None),
        ("plans only", Some(Arc::new(GatherPlanCache::plans_only()))),
        ("plans + zero-skip", Some(full.clone())),
    ];
    let mut golden: Option<String> = None;
    for (label, plans) in variants {
        let opts = SimOptions { gather_plans: plans, ..opts.clone() };
        for jobs in [1, 4] {
            let report = cosim_from_traces(&traces, &cfg, &opts, true, jobs).unwrap();
            assert!(report.replayed && report.backend == "exact");
            let bytes = report.to_json().dump();
            match &golden {
                Some(g) => assert_eq!(
                    g, &bytes,
                    "{label} at jobs {jobs}: optimized report diverged"
                ),
                None => golden = Some(bytes),
            }
        }
    }
    // The transparent runs above really exercised the machinery.
    let s = full.stats();
    assert!(s.words_gathered > 0, "{s:?}");
}
