//! Property-based tests (via `agos::util::prop`) on the coordinator and
//! simulator invariants DESIGN.md §7 prescribes.

use agos::config::{AcceleratorConfig, Scheme, SimOptions};
use agos::prop_assert;
use agos::sim::{redistribute, simulate_layer, synapse_passes, LayerTask, PeModel};
use agos::sparsity::{analyze_network, encode_tensor, gradient_sparsity, Bitmap};
use agos::nn::{Network, Shape};
use agos::util::json::Json;
use agos::util::prop::{check, Gen};
use agos::util::rng::Pcg32;

fn arb_task(g: &mut Gen) -> LayerTask {
    let m = g.usize_in(1, 256);
    let u = g.usize_in(1, 64);
    let v = g.usize_in(1, 64);
    let crs = g.usize_in(1, 5000) as f64;
    LayerTask {
        name: "prop".into(),
        m,
        u,
        v,
        crs,
        in_sparsity: g.bool().then(|| g.f64_in(0.0, 0.95)),
        out_sparsity: g.bool().then(|| g.f64_in(0.0, 0.95)),
        input_elems: (m * u * v) as f64,
        weight_elems: m as f64 * crs,
        geom: Default::default(),
        op_chans: g.usize_in(1, 64),
    }
}

#[test]
fn prop_dense_scheme_performs_exactly_dense_macs() {
    check("dense==dense-macs", |g| {
        let task = arb_task(g);
        let cfg = AcceleratorConfig::default();
        let opts = SimOptions::default();
        let mut rng = Pcg32::new(g.rng.next_u64());
        let r = simulate_layer(&task, &cfg, &opts, Scheme::Dense, &mut rng);
        prop_assert!(
            (r.performed_macs - r.dense_macs).abs() <= 1e-6 * r.dense_macs.max(1.0),
            "performed {} vs dense {}",
            r.performed_macs,
            r.dense_macs
        );
        Ok(())
    });
}

#[test]
fn prop_speedup_monotone_in_scheme() {
    check("scheme-monotone", |g| {
        let task = arb_task(g);
        let cfg = AcceleratorConfig::default();
        let opts = SimOptions::default();
        let seed = g.rng.next_u64();
        let mut cycles = Vec::new();
        for scheme in Scheme::ALL {
            let mut rng = Pcg32::new(seed);
            cycles.push(simulate_layer(&task, &cfg, &opts, scheme, &mut rng).cycles);
        }
        // DC >= IN >= IN+OUT; WR within tolerance of IN+OUT. The 2%
        // slack absorbs stochastic tile-jitter noise: the schemes draw
        // different jitter sequences, so with near-zero sparsity their
        // makespans differ by sampling noise only.
        prop_assert!(cycles[0] >= cycles[1] * 0.98, "DC {} < IN {}", cycles[0], cycles[1]);
        prop_assert!(cycles[1] >= cycles[2] * 0.98, "IN {} < IN+OUT {}", cycles[1], cycles[2]);
        prop_assert!(cycles[3] <= cycles[2] * 1.02, "WR {} > IN+OUT {}", cycles[3], cycles[2]);
        Ok(())
    });
}

#[test]
fn prop_wdu_conserves_and_never_regresses() {
    check("wdu-invariants", |g| {
        let n = g.usize_in(1, 300);
        let work = g.vec(n, |g| g.f64_in(0.0, 10_000.0));
        let threshold = g.f64_in(0.05, 1.0);
        let overhead = g.f64_in(0.0, 0.2);
        let base_makespan = work.iter().cloned().fold(0.0, f64::max);
        let out = redistribute(&work, threshold, overhead);
        prop_assert!(out.completion.len() == n);
        // never worse than no redistribution (modest overhead bound)
        prop_assert!(
            out.makespan <= base_makespan * 1.01 + 1.0,
            "makespan {} vs base {base_makespan}",
            out.makespan
        );
        // completion of every tile is bounded by the makespan
        for c in &out.completion {
            prop_assert!(*c <= out.makespan + 1e-9);
        }
        // total busy time is conserved within overhead
        let total_in: f64 = work.iter().sum();
        let total_out: f64 = out.completion.iter().sum();
        prop_assert!(
            total_out + 1e-6 >= total_in.min(base_makespan),
            "work lost: {total_out} < {total_in}"
        );
        Ok(())
    });
}

#[test]
fn prop_encoder_roundtrip() {
    check("encoder-roundtrip", |g| {
        let n = g.usize_in(0, 400);
        let sparsity = g.f64_in(0.0, 1.0);
        let values: Vec<f32> = (0..n)
            .map(|_| if g.rng.f64() < sparsity { 0.0 } else { g.rng.f32() + 0.001 })
            .collect();
        let enc = encode_tensor(&values);
        // decode every group and compare against the raw positions
        let mut decoded = Vec::new();
        for gi in 0..enc.groups.len() {
            decoded.extend(agos::sparsity::decode_group(&enc, gi));
        }
        let expect: Vec<usize> =
            values.iter().enumerate().filter(|(_, v)| **v != 0.0).map(|(i, _)| i).collect();
        prop_assert!(decoded == expect, "decode mismatch at n={n}");
        prop_assert!(enc.nz() == expect.len());
        Ok(())
    });
}

#[test]
fn prop_bitmap_counts_match_values() {
    check("bitmap-counts", |g| {
        let c = g.usize_in(1, 8);
        let h = g.usize_in(1, 12);
        let w = g.usize_in(1, 12);
        let shape = Shape::new(c, h, w);
        let values: Vec<f32> =
            (0..shape.len()).map(|_| if g.bool() { 0.0 } else { 1.0 }).collect();
        let bm = Bitmap::from_values(shape, &values);
        let expect_nz = values.iter().filter(|v| **v != 0.0).count();
        prop_assert!(bm.count_nz() == expect_nz);
        // per-channel sums must equal the total
        let per: usize = (0..c).map(|ci| bm.wc_nz(ci)).sum();
        prop_assert!(per == expect_nz);
        Ok(())
    });
}

#[test]
fn prop_gradient_sparsity_bounded_and_bn_densifies() {
    check("gradient-sparsity-bounds", |g| {
        // random conv/relu/bn chain
        let mut net = Network::new("prop");
        let x = net.input(4, 16, 16);
        let mut cur = x;
        let depth = g.usize_in(1, 6);
        for i in 0..depth {
            let c = net.conv(&format!("c{i}"), cur, 4, 3, 1, 1);
            let with_bn = g.bool();
            let pre = if with_bn { net.bn(&format!("b{i}"), c) } else { c };
            cur = net.relu(&format!("r{i}"), pre);
        }
        net.softmax("sm", cur);
        let mut fwd = vec![0.0; net.len()];
        for l in net.layers() {
            if l.kind.is_relu() {
                fwd[l.id] = g.f64_in(0.1, 0.9);
            }
        }
        let gs = gradient_sparsity(&net, &fwd);
        for (id, s) in gs.iter().enumerate() {
            prop_assert!((0.0..=1.0).contains(s), "layer {id}: {s}");
        }
        // every BN output carries dense gradient at the conv below
        let opps = analyze_network(&net, &fwd);
        for o in &opps {
            let producer_consumers = net.consumers(o.layer);
            if producer_consumers
                .iter()
                .any(|&k| matches!(net.layer(k).kind, agos::nn::LayerKind::BatchNorm))
            {
                prop_assert!(o.bp_input.is_none(), "{}: BN must densify", o.name);
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pe_cycles_bounded_by_dense() {
    check("pe-cycles-bounds", |g| {
        let pe = PeModel::from_config(&AcceleratorConfig::default());
        let crs = g.usize_in(1, 8000) as f64;
        let s = g.f64_in(0.0, 1.0);
        let (sparse, macs) = pe.cycles_per_output(crs, s);
        let dense = pe.dense_cycles_per_output(crs);
        prop_assert!(sparse <= dense * 1.0001, "sparse {sparse} > dense {dense}");
        prop_assert!(sparse >= 1.0);
        prop_assert!(macs <= crs + 1e-9);
        Ok(())
    });
}

#[test]
fn prop_synapse_passes_cover_crs() {
    check("blocking-coverage", |g| {
        let crs = g.usize_in(1, 100_000);
        let cap = [256, 512, 1024, 2048][g.usize_in(0, 3)];
        let passes = synapse_passes(crs, cap);
        prop_assert!(passes * cap >= crs, "passes {passes} x {cap} < {crs}");
        prop_assert!((passes - 1) * cap < crs, "one pass too many");
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip() {
    fn arb_json(g: &mut Gen, depth: usize) -> Json {
        match if depth == 0 { g.usize_in(0, 3) } else { g.usize_in(0, 5) } {
            0 => Json::Null,
            1 => Json::Bool(g.bool()),
            2 => Json::Num((g.f64_in(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => Json::Str(
                (0..g.usize_in(0, 12))
                    .map(|_| *g.choose(&['a', 'b', '"', '\\', 'é', '\n', '7']))
                    .collect(),
            ),
            4 => {
                let n = g.usize_in(0, 4);
                Json::Arr(g.vec(n, |g| arb_json(g, depth - 1)))
            }
            _ => {
                let n = g.usize_in(0, 4);
                let mut o = Json::obj();
                for i in 0..n {
                    let key = format!("k{i}");
                    o.set(&key, arb_json(g, depth - 1));
                }
                o
            }
        }
    }
    check("json-roundtrip", |g| {
        let j = arb_json(g, 3);
        let text = j.pretty();
        let back = Json::parse(&text).map_err(|e| format!("parse: {e}"))?;
        prop_assert!(back == j, "roundtrip mismatch:\n{text}");
        Ok(())
    });
}
