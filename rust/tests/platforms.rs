//! Platform-comparison contract (ISSUE 10 acceptance):
//!
//! * **Ordering**: for every zoo network at small batch, a
//!   dense-execution platform never models a lower iteration latency
//!   than the input-sparsity-exploiting design at the same peak
//!   throughput (DaDianNao vs CNVLUTIN), each measured skip mechanism
//!   never beats dense execution at its own peak, and "This Work" stays
//!   fastest among the simulator-consuming accelerator rows.
//! * **Determinism**: the full platform table and the `platforms`
//!   figure are bit-identical between `--jobs 1` and `--jobs 4` runs.
//! * **Replay sensitivity**: swapping a trace's measured-mean model for
//!   its real replayed bitmaps moves the measured-sparsity rows.

use agos::baselines::{
    all_platforms, iteration_latency_ms, measured_latency_ms, measured_summaries, Platform,
    PlatformKind,
};
use agos::config::{AcceleratorConfig, BitmapPattern, SimOptions};
use agos::coordinator::PreparedCosim;
use agos::nn::zoo;
use agos::report::{benchmarks_from_trace, figure_platforms, table2_platforms, ReportCtx};
use agos::sim::SweepRunner;
use agos::sparsity::{capture_synthetic_trace, SparsityModel};

/// Rows whose latency is produced by consuming simulator output —
/// cycle counts (SimulatorBacked) or measured density maps
/// (MeasuredSparse). "This Work" must beat every one of them.
fn simulator_consuming(platforms: &[Platform]) -> Vec<&Platform> {
    platforms
        .iter()
        .filter(|p| {
            matches!(
                p.kind,
                PlatformKind::SimulatorBacked { .. } | PlatformKind::MeasuredSparse { .. }
            )
        })
        .collect()
}

#[test]
fn platform_ordering_holds_across_the_zoo() {
    let cfg = AcceleratorConfig::default();
    let opts = SimOptions { batch: 2, ..SimOptions::default() };
    let model = SparsityModel::synthetic(opts.seed);
    let runner = SweepRunner::new(0);
    let platforms = all_platforms(&cfg);
    let ours_row = platforms.last().unwrap();
    let rivals = simulator_consuming(&platforms);
    assert_eq!(rivals.len(), 5, "DDN, CNV and the three measured rows");

    let (ddn, cnv) = (&platforms[2], &platforms[3]);
    assert_eq!(ddn.peak_gops, cnv.peak_gops, "same-peak premise of the dense/sparse pair");

    for net in zoo::all_networks() {
        let lat = |p: &Platform| iteration_latency_ms(p, &net, &cfg, &opts, &model, &runner);

        // This Work is the fastest simulator-backed accelerator on every
        // zoo network: DDN/CNV run the same simulated workload under a
        // weaker scheme, a slower clock and a mapping penalty. On the
        // paper's benchmark pair the claim extends to the idealized
        // measured-sparsity rows too (their peak/penalty margins are
        // calibrated on these networks).
        let ours = lat(ours_row);
        assert!(ours > 0.0, "{}", net.name);
        let full_field = net.name == "vgg16" || net.name == "resnet18";
        for row in &rivals {
            if !full_field && matches!(row.kind, PlatformKind::MeasuredSparse { .. }) {
                continue;
            }
            let other = lat(row);
            assert!(
                ours < other,
                "{}: This Work ({ours:.3} ms) must beat {} ({other:.3} ms)",
                net.name,
                row.name
            );
        }

        // Dense execution never undercuts input-sparse at the same peak:
        // identical datapath specs, CNVLUTIN only *removes* work.
        assert!(
            lat(ddn) > lat(cnv),
            "{}: dense DaDianNao must trail input-sparse CNVLUTIN",
            net.name
        );

        // No measured skip mechanism beats dense execution at its own
        // published peak — effective density never exceeds 1.
        for row in &rivals {
            if let PlatformKind::MeasuredSparse { mechanism, mapping_penalty } = row.kind {
                let (d_in, d_io) = measured_summaries(&net, &cfg, &opts, &model, &runner);
                let sparse =
                    measured_latency_ms(mechanism, mapping_penalty, row.peak_gops, &d_in, &d_io);
                let dense = mapping_penalty * 2.0 * d_in.total_dense_macs()
                    / (row.peak_gops * 1e9)
                    * 1e3;
                assert!(
                    sparse <= dense * (1.0 + 1e-12),
                    "{}: {} ({sparse:.3} ms) must not beat its dense bound ({dense:.3} ms)",
                    net.name,
                    row.name
                );
            }
        }
    }
}

#[test]
fn platform_table_is_bit_identical_across_jobs_levels() {
    let at_jobs = |jobs: usize| {
        let mut ctx = ReportCtx::with_batch(2);
        ctx.sweep = SweepRunner::new(jobs);
        let table = table2_platforms(&ctx).to_json().dump();
        let figure = figure_platforms(&ctx).to_json().dump();
        (table, figure)
    };
    let (t1, f1) = at_jobs(1);
    let (t4, f4) = at_jobs(4);
    assert_eq!(t1, t4, "table2 must not depend on the --jobs level");
    assert_eq!(f1, f4, "platforms figure must not depend on the --jobs level");
}

#[test]
fn replayed_trace_moves_the_measured_rows() {
    let net = zoo::agos_cnn();
    let capture_model = SparsityModel::synthetic(5);
    let traces = capture_synthetic_trace(&net, &capture_model, 2, BitmapPattern::Iid, 0);
    let prep = PreparedCosim::new_owned(traces, true).unwrap();

    // Same trace, same seed: one benchmark replays the real bitmaps,
    // the other simulates under the trace's measured-mean model.
    let table_with = |replay: bool| {
        let mut ctx = ReportCtx::with_batch(1);
        ctx.benchmarks = Some(benchmarks_from_trace(&prep, &ctx.opts, replay).unwrap());
        table2_platforms(&ctx)
    };
    let replayed = table_with(true);
    let modeled = table_with(false);

    let col = format!("{}_ms", prep.network());
    for name in ["SparseNN", "SparseTrain", "TensorDash", "This Work"] {
        let r = replayed.value(name, &col).unwrap();
        let m = modeled.value(name, &col).unwrap();
        assert!(r > 0.0 && m > 0.0, "{name}: {r} / {m}");
        assert!(
            (r - m).abs() > 1e-9 * m,
            "{name}: replayed bitmaps must move the measured latency ({r} vs {m})"
        );
    }
}
