//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access (DESIGN.md §0), so this
//! vendored crate provides the subset of the real `anyhow` API the
//! repository uses — `Error`, `Result`, the `Context` extension trait for
//! both `Result` and `Option`, and the `anyhow!` / `bail!` / `ensure!`
//! macros — with identical call-site semantics:
//!
//! * `?` converts any `std::error::Error + Send + Sync + 'static` into
//!   [`Error`], preserving its source chain as context frames.
//! * `.context(..)` / `.with_context(..)` push a new outermost message.
//! * `{e}` prints the outermost message, `{e:#}` the whole chain
//!   colon-separated, `{e:?}` the chain in "Caused by" form.
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error` — the blanket `From` impl depends on that.

use std::fmt;

/// An error message with an optional chain of underlying causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Construct from a displayable message (the `anyhow!` macro's core).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), source: None }
    }

    /// Wrap with a new outermost context message.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: c.to_string(), source: Some(Box::new(self)) }
    }

    /// The context chain, outermost message first.
    pub fn chain(&self) -> Vec<&str> {
        let mut out = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        out
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.source.as_deref();
        let mut first = true;
        while let Some(e) = cur {
            if first {
                write!(f, "\n\nCaused by:")?;
                first = false;
            }
            write!(f, "\n    {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Flatten the std source chain into context frames.
        let mut msgs: Vec<String> = Vec::new();
        let mut cur: Option<&(dyn std::error::Error + 'static)> = Some(&e);
        while let Some(c) = cur {
            msgs.push(c.to_string());
            cur = c.source();
        }
        let mut err: Option<Error> = None;
        while let Some(m) = msgs.pop() {
            err = Some(Error { msg: m, source: err.map(Box::new) });
        }
        err.expect("error has at least one message")
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(c)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(format!($($arg)+))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::Error::msg(format!($($arg)+)))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::Error::msg(format!($($arg)+)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{e}"), "missing file");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("loading config").unwrap_err();
        assert_eq!(format!("{e}"), "loading config");
        assert_eq!(format!("{e:#}"), "loading config: missing file");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("want {}", 42)).unwrap_err();
        assert_eq!(format!("{e}"), "want 42");

        assert_eq!(Some(7u32).context("x").unwrap(), 7);
    }

    #[test]
    fn context_nests_on_anyhow_results() {
        fn inner() -> Result<()> {
            bail!("deep failure {}", 1)
        }
        let e = inner().context("mid").context("outer").unwrap_err();
        assert_eq!(e.chain(), vec!["outer", "mid", "deep failure 1"]);
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn ensure_and_bail_control_flow() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            ensure!(x != 3);
            Ok(x)
        }
        assert_eq!(check(5).unwrap(), 5);
        assert_eq!(format!("{}", check(-1).unwrap_err()), "negative: -1");
        assert!(format!("{}", check(3).unwrap_err()).contains("x != 3"));
    }

    #[test]
    fn anyhow_macro_formats() {
        let e = anyhow!("bad value '{}'", "q");
        assert_eq!(format!("{e}"), "bad value 'q'");
    }
}
