//! Bench + regeneration harness for: Fig 17 node tile-latency variation.
//!
//! Prints the paper artifact (same rows/series the paper reports) and
//! measures the end-to-end generation cost. `AGOS_BENCH_QUICK=1` for a
//! smoke run.

use agos::report::{generate, ReportCtx};
use agos::util::bench::Bench;

fn main() {
    let quick = std::env::var("AGOS_BENCH_QUICK").is_ok();
    let batch = if quick { 2 } else { 16 };
    let ctx = ReportCtx::with_batch(batch);

    // Regenerate and print the paper artifact once.
    for id in "fig17".split_whitespace() {
        for fig in generate(id, &ctx).expect("generate") {
            print!("{}", fig.render());
            println!();
        }
    }

    // Measure the generation cost.
    let mut b = Bench::new("fig17_node");
    for id in "fig17".split_whitespace() {
        // Cold context per iteration: reusing `ctx` would serve repeat
        // iterations from its sweep cache and time only map lookups.
        b.case(id, || {
            let cold = ReportCtx::with_batch(batch);
            generate(id, &cold).unwrap().len()
        });
    }
    b.finish();
}
