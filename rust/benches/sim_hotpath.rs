//! Micro-benchmarks of the simulator hot paths — the targets of the
//! performance pass (EXPERIMENTS.md §Perf) — plus the parallel sweep
//! executor, whose sequential-vs-parallel wall-clock for a GoogLeNet
//! all-scheme sweep is persisted to `BENCH_sweep.json` so the perf
//! trajectory is tracked across PRs.

use std::sync::Arc;

use agos::config::{AcceleratorConfig, BitmapPattern, ExecBackend, GatherMode, Scheme, SimOptions};
use agos::nn::{zoo, Shape};
use agos::sim::{
    redistribute, simulate_layer, simulate_network, GatherPlanCache, LayerTask, PeModel,
    ReplayBank, SkipStats, SweepPlan, SweepRunner, TaskGeom,
};
use agos::sparsity::{capture_synthetic_trace, Bitmap, SparsityModel};
use agos::trace::{LayerTrace, StepTrace, TraceFile, TraceFormat, TraceWriter};
use agos::util::bench::{black_box, Bench};
use agos::util::json::Json;
use agos::util::rng::Pcg32;

fn main() {
    let cfg = AcceleratorConfig::default();
    let opts = SimOptions::default();
    let mut b = Bench::new("sim_hotpath");

    // PE per-output model — called once per (tile, layer, image).
    let pe = PeModel::from_config(&cfg);
    b.case("pe_cycles_per_output_x1000", || {
        let mut acc = 0.0;
        for i in 0..1000 {
            let s = (i % 10) as f64 / 10.0;
            acc += pe.cycles_per_output(black_box(1152.0), black_box(s)).0;
        }
        acc
    });

    // Layer execution — 256 tiles with jitter.
    let task = LayerTask {
        name: "bench".into(),
        m: 128,
        u: 28,
        v: 28,
        crs: 1152.0,
        in_sparsity: Some(0.5),
        out_sparsity: Some(0.5),
        input_elems: 128.0 * 30.0 * 30.0,
        weight_elems: 128.0 * 1152.0,
        geom: Default::default(),
        op_chans: 128,
    };
    b.case("simulate_layer_inoutwr", || {
        let mut rng = Pcg32::new(7);
        simulate_layer(&task, &cfg, &opts, Scheme::InOutWr, &mut rng).cycles
    });

    // WDU event loop on a skewed 256-tile workload.
    let mut rng = Pcg32::new(5);
    let work: Vec<f64> = (0..256).map(|_| 1000.0 * (1.0 + 0.3 * rng.gauss()).max(0.05)).collect();
    b.case("wdu_redistribute_256", || redistribute(black_box(&work), 0.3, 0.05).makespan);

    // Whole-network sweeps (the figure-generation workhorse).
    let model = SparsityModel::synthetic(1);
    let small_opts = SimOptions { batch: 1, ..SimOptions::default() };
    for net in [zoo::resnet18(), zoo::vgg16()] {
        b.case(&format!("simulate_{}_b1", net.name), || {
            simulate_network(&net, &cfg, &small_opts, &model, Scheme::InOutWr).total_cycles()
        });
    }
    let dn = zoo::densenet121();
    b.case("simulate_densenet121_b1", || {
        simulate_network(&dn, &cfg, &small_opts, &model, Scheme::InOutWr).total_cycles()
    });

    // Sweep executor: GoogLeNet under all four schemes, cold cache each
    // iteration, sequential vs. all-core parallel.
    let gnet = zoo::googlenet();
    let jobs = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let run_sweep = |threads: usize| {
        let runner = SweepRunner::new(threads);
        let plan =
            SweepPlan::grid(std::slice::from_ref(&gnet), &Scheme::ALL, &cfg, &small_opts);
        runner.run(&plan, &model).iter().map(|r| r.total_cycles()).sum::<f64>()
    };
    b.case("sweep_googlenet_4schemes_jobs1", || run_sweep(1));
    if jobs > 1 {
        b.case(&format!("sweep_googlenet_4schemes_jobs{jobs}"), || run_sweep(jobs));
    }

    // Execution backends head-to-head on the traced CNN (the exact
    // backend's production-size configuration, 64 sampled outputs/tile).
    let anet = zoo::agos_cnn();
    let analytic_opts = SimOptions {
        batch: 1,
        backend: ExecBackend::Analytic,
        ..SimOptions::default()
    };
    let exact_opts = SimOptions {
        batch: 1,
        backend: ExecBackend::Exact,
        exact_outputs_per_tile: 64,
        ..SimOptions::default()
    };
    b.case("backend_analytic_agos_b1", || {
        simulate_network(&anet, &cfg, &analytic_opts, &model, Scheme::InOutWr).total_cycles()
    });
    b.case("backend_exact_agos_b1", || {
        simulate_network(&anet, &cfg, &exact_opts, &model, Scheme::InOutWr).total_cycles()
    });

    // Replay vs sample on the exact backend: same workload, patterns
    // sliced from a captured trace instead of drawn from the stream.
    let trace = capture_synthetic_trace(&anet, &model, 2, BitmapPattern::Iid, 2);
    let bank = ReplayBank::from_trace(&anet, &trace).expect("synthesized capture");
    let replay_opts = SimOptions {
        trace_fingerprint: Some(trace.fingerprint()),
        replay: Some(Arc::new(bank)),
        ..exact_opts.clone()
    };
    b.case("backend_exact_replay_agos_b1", || {
        simulate_network(&anet, &cfg, &replay_opts, &model, Scheme::InOutWr).total_cycles()
    });
    // The legacy streaming-slice window, kept as the gather baseline:
    // geometry-exact replay must not cost materially more than the
    // approximation it replaced (BENCH_baseline.json gates the ratio).
    let replay_stream_opts =
        SimOptions { gather: GatherMode::Streaming, ..replay_opts.clone() };
    b.case("backend_exact_replay_stream_agos_b1", || {
        simulate_network(&anet, &cfg, &replay_stream_opts, &model, Scheme::InOutWr).total_cycles()
    });
    // The same replay with gather plans disabled — the per-window
    // re-derivation the plan cache replaces (results are bit-identical;
    // only the wall-clock differs).
    let replay_noplan_opts = SimOptions { gather_plans: None, ..replay_opts.clone() };
    b.case("backend_exact_replay_noplan_agos_b1", || {
        simulate_network(&anet, &cfg, &replay_noplan_opts, &model, Scheme::InOutWr).total_cycles()
    });

    // Gather micro-bench: one conv plane's receptive-field assembly,
    // direct (`Bitmap::gather_window_words`) vs plan-driven vs
    // plan-driven with RLE zero-skip, on a realistically blob-sparse map
    // (~5% dense → most operand words are skippable). The two ratio rows
    // the bench gate tracks (`exact_gather_plan_speedup`,
    // `exact_zero_skip_speedup`) come from these three cases.
    let gshape = Shape::new(64, 28, 28);
    let gconv = TaskGeom::Conv { r: 3, s: 3, stride: 1, pad: 1, dw: false };
    let gmap = Bitmap::sample_blobs(gshape, 0.05, 3, &mut Pcg32::new(11));
    let gruns = gmap.run_index();
    let gcache = GatherPlanCache::new();
    let gplan = gcache.plan_for(gshape, gconv, 28, 28).expect("conv plans");
    b.case("gather_direct_conv3x3_64x28x28", || {
        let mut out = Vec::new();
        let mut acc = 0usize;
        for y in 0..28usize {
            for x in 0..28usize {
                acc += gmap.gather_window_words(
                    0,
                    64,
                    y as isize - 1,
                    x as isize - 1,
                    3,
                    3,
                    black_box(&mut out),
                );
            }
        }
        black_box(acc)
    });
    let planned_walk = |runs: Option<&agos::sparsity::RunIndex>| {
        let mut out = Vec::new();
        let mut stats = SkipStats::default();
        let mut acc = 0usize;
        for y in 0..28usize {
            for x in 0..28usize {
                match gplan.gather(&gmap, runs, 0, y, x, &mut stats, black_box(&mut out)) {
                    agos::sim::PlannedGather::Words { len }
                    | agos::sim::PlannedGather::AllOnes { len } => acc += len,
                }
            }
        }
        black_box(acc)
    };
    b.case("gather_planned_conv3x3_64x28x28", || planned_walk(None));
    b.case("gather_planned_skip_conv3x3_64x28x28", || planned_walk(Some(&gruns)));

    // Bitmap drain walks: the legacy per-bool channel expansion (what
    // `Bitmap::channel_bits` cost the hot loop before the word refactor)
    // vs the packed word/popcount iterator (`channel_words`/`wc_nz`).
    let bm = Bitmap::sample(Shape::new(64, 56, 56), 0.5, &mut Pcg32::new(3));
    b.case("bitmap_channel_bool_walk_64x56x56", || {
        let mut n = 0usize;
        for c in 0..64 {
            let bits: Vec<bool> =
                (0..56 * 56).map(|i| bm.get(c, i / 56, i % 56)).collect();
            n += bits.iter().filter(|b| **b).count();
        }
        black_box(n)
    });
    b.case("bitmap_channel_word_walk_64x56x56", || {
        let mut n = 0usize;
        for c in 0..64 {
            n += bm.wc_nz(c);
        }
        black_box(n)
    });

    // TraceFile v3 codec on a realistically sparse blobbed map (the
    // batch-wide capture payload): RLE decode throughput next to the
    // legacy hex decode, plus the deterministic payload-size ratio the
    // bench gate tracks (seeded map → identical on every host).
    let v3_map = Bitmap::sample_blobs(Shape::new(64, 56, 56), 0.03, 4, &mut Pcg32::new(9));
    let v3_rle = v3_map.encode_rle();
    let v3_hex = v3_map.encode_hex();
    b.case("trace_v3_encode_rle_64x56x56", || black_box(v3_map.encode_rle().len()));
    b.case("trace_v3_decode_rle_64x56x56", || {
        Bitmap::decode_rle(v3_map.shape, black_box(&v3_rle)).unwrap().count_nz()
    });
    b.case("trace_v2_decode_hex_64x56x56", || {
        Bitmap::decode_hex(v3_map.shape, black_box(&v3_hex)).unwrap().count_nz()
    });

    // TraceFile v4 binary container on the same seeded payload (two
    // correlated steps so the delta chain is exercised): in-memory
    // container encode/decode next to the v3 JSON-text decode, one
    // bounded-memory streaming append per iteration, and the two gated
    // deterministic/ratio rows (`trace_v4_decode_vs_v3`,
    // `trace_v4_bytes_ratio`).
    let v4_grad = v3_map.and(&Bitmap::sample(Shape::new(64, 56, 56), 0.5, &mut Pcg32::new(10)));
    let mk_container = |format: TraceFormat| TraceFile {
        network: "bench".into(),
        format,
        steps: (0..2usize)
            .map(|step| StepTrace {
                step,
                loss: 2.0,
                layers: vec![LayerTrace::from_bitmaps("relu1", v3_map.clone(), v4_grad.clone())],
            })
            .collect(),
    };
    let v4_container = mk_container(TraceFormat::V4);
    let v4_bytes = v4_container.encode_v4().expect("v4 encode");
    let v3_text = mk_container(TraceFormat::V3).to_json().dump();
    b.case("trace_v4_encode_container", || {
        black_box(v4_container.encode_v4().unwrap().len())
    });
    b.case("trace_v4_decode_container", || {
        TraceFile::decode_v4(black_box(&v4_bytes)).unwrap().steps.len()
    });
    b.case("trace_v3_decode_container", || {
        TraceFile::from_json(&Json::parse(black_box(&v3_text)).unwrap()).unwrap().steps.len()
    });
    let stream_dir = std::env::temp_dir().join("agos_bench_v4_stream");
    std::fs::create_dir_all(&stream_dir).expect("temp dir");
    let stream_path = stream_dir.join("stream.trace.bin");
    b.case("trace_v4_stream_append_2steps", || {
        let mut w = TraceWriter::create(&stream_path, &v4_container.network).unwrap();
        for s in &v4_container.steps {
            w.append(s).unwrap();
        }
        w.finish().unwrap()
    });
    std::fs::remove_dir_all(&stream_dir).ok();

    // `agos serve` warm path vs the cold one-shot (ISSUE 8). Cold: every
    // request re-loads the trace container, rebuilds the replay bank and
    // re-derives gather plans — the one-shot CLI's work minus process
    // start, so the ratio below is a *floor* on the real-world win.
    // Warm: the same request round-trips a resident server's Unix socket
    // and is answered from the in-memory sweep cache. The mean ratio is
    // the gated `serve_warm_vs_cold_speedup` row.
    #[cfg(unix)]
    {
        use agos::coordinator::cosim_from_traces_owned;
        use agos::serve::{Client, ServeOptions, Server};

        let dir = std::env::temp_dir().join("agos_bench_serve");
        std::fs::create_dir_all(&dir).expect("temp dir");
        let trace_path = dir.join("bench.trace.bin");
        trace.save(&trace_path).expect("trace save");

        b.case("serve_cold_cosim_request", || {
            // Fresh options per request: a cold process starts with an
            // empty gather-plan cache too.
            let cold_opts = SimOptions {
                batch: 1,
                backend: ExecBackend::Exact,
                exact_outputs_per_tile: 8,
                ..SimOptions::default()
            };
            let traces = TraceFile::load(&trace_path).unwrap();
            cosim_from_traces_owned(traces, &cfg, &cold_opts, true, 1)
                .unwrap()
                .to_json()
                .dump()
                .len()
        });

        let server = Server::bind(ServeOptions {
            socket: dir.join("bench.sock"),
            jobs: 1,
            workers: 2,
            cache_path: None,
        })
        .expect("bind bench server");
        let socket = server.socket().to_path_buf();
        let handle = std::thread::spawn(move || server.run());
        let mut client = Client::connect_retry(&socket, std::time::Duration::from_secs(10))
            .expect("connect to bench server");
        let req = Json::parse(&format!(
            r#"{{"cmd":"cosim","traces":"{}","replay":true,"backend":"exact","batch":1,"exact_cap":8}}"#,
            trace_path.to_str().expect("utf-8 temp path")
        ))
        .unwrap();
        client.request(&req).expect("warm-up request");
        b.case("serve_warm_cosim_request", || client.request(&req).unwrap().dump().len());
        client.request(&Json::parse(r#"{"cmd":"shutdown"}"#).unwrap()).expect("shutdown");
        handle.join().expect("serve thread").expect("serve loop");
        std::fs::remove_dir_all(&dir).ok();
    }

    // Platform table (ISSUE 10): cold builds Table 2 against an empty
    // sweep cache — simulating both benchmark networks under every
    // scheme the rows consume — while warm rebuilds it against a primed
    // cache, leaving only density extraction and formatting. The ratio
    // is the gated `table2_warm_vs_cold_speedup` row.
    {
        use agos::report::{table2_platforms, ReportCtx};
        b.case("table2_platforms_cold", || {
            let ctx = ReportCtx::with_batch(1);
            table2_platforms(&ctx).to_json().dump().len()
        });
        let warm_ctx = ReportCtx::with_batch(1);
        table2_platforms(&warm_ctx);
        b.case("table2_platforms_warm", || {
            table2_platforms(&warm_ctx).to_json().dump().len()
        });
    }
    b.finish();

    // Persist the sweep trajectory point (sequential vs parallel).
    let find = |suffix: &str| {
        b.results()
            .iter()
            .find(|(label, _)| label.ends_with(suffix))
            .map(|(_, s)| *s)
            .expect("bench case ran")
    };
    let seq = find("_jobs1");
    let par = if jobs > 1 { find(&format!("_jobs{jobs}")) } else { seq };
    let analytic = find("backend_analytic_agos_b1");
    let exact = find("backend_exact_agos_b1");
    let replay = find("backend_exact_replay_agos_b1");
    let replay_stream = find("backend_exact_replay_stream_agos_b1");
    let replay_noplan = find("backend_exact_replay_noplan_agos_b1");
    let gather_direct = find("gather_direct_conv3x3_64x28x28");
    let gather_planned = find("gather_planned_conv3x3_64x28x28");
    let gather_skip = find("gather_planned_skip_conv3x3_64x28x28");
    let bool_walk = find("bitmap_channel_bool_walk_64x56x56");
    let word_walk = find("bitmap_channel_word_walk_64x56x56");
    let v3_decode = find("trace_v3_decode_rle_64x56x56");
    let hex_decode = find("trace_v2_decode_hex_64x56x56");
    let v4_encode = find("trace_v4_encode_container");
    let v4_decode = find("trace_v4_decode_container");
    let v3c_decode = find("trace_v3_decode_container");
    let v4_stream = find("trace_v4_stream_append_2steps");
    let mut pairs: Vec<(&str, Json)> = vec![
        ("bench", "sweep_googlenet_4schemes".into()),
        ("network", "googlenet".into()),
        ("schemes", 4u64.into()),
        ("batch", small_opts.batch.into()),
        ("jobs", jobs.into()),
        ("seq_mean_s", seq.mean.into()),
        ("seq_std_s", seq.std.into()),
        ("par_mean_s", par.mean.into()),
        ("par_std_s", par.std.into()),
        ("speedup", (seq.mean / par.mean).into()),
        // Backend head-to-head (agos_cnn b1, IN+OUT+WR, 64 outputs/tile).
        ("backend_analytic_mean_s", analytic.mean.into()),
        ("backend_analytic_std_s", analytic.std.into()),
        ("backend_exact_mean_s", exact.mean.into()),
        ("backend_exact_std_s", exact.std.into()),
        ("backend_exact_slowdown", (exact.mean / analytic.mean).into()),
        // Replay-vs-sample on the exact backend (agos_cnn b1).
        ("backend_exact_replay_mean_s", replay.mean.into()),
        ("backend_exact_replay_std_s", replay.std.into()),
        ("backend_replay_vs_sampled", (replay.mean / exact.mean).into()),
        // Geometry-exact gather vs the legacy streaming slice.
        ("backend_exact_replay_stream_mean_s", replay_stream.mean.into()),
        ("replay_geometry_vs_streaming", (replay.mean / replay_stream.mean).into()),
        // Gather plans + RLE zero-skip (PR 6). Plan speedup is the
        // per-window re-derivation cost the plan cache eliminates;
        // zero-skip is the further win from eliding all-zero operand
        // words on a blob-sparse map. Both ratios are gated.
        ("backend_exact_replay_noplan_mean_s", replay_noplan.mean.into()),
        ("gather_direct_mean_s", gather_direct.mean.into()),
        ("gather_planned_mean_s", gather_planned.mean.into()),
        ("gather_planned_skip_mean_s", gather_skip.mean.into()),
        ("exact_gather_plan_speedup", (gather_direct.mean / gather_planned.mean).into()),
        ("exact_zero_skip_speedup", (gather_planned.mean / gather_skip.mean).into()),
        // Word-level drain refactor: per-bool channel walk vs packed
        // word/popcount walk over a 64x56x56 map.
        ("bitmap_bool_walk_mean_s", bool_walk.mean.into()),
        ("bitmap_word_walk_mean_s", word_walk.mean.into()),
        ("bitmap_word_walk_speedup", (bool_walk.mean / word_walk.mean).into()),
        // TraceFile v3 codec: decode throughput vs the hex decode and
        // the deterministic payload-size ratio (seeded blob map).
        ("trace_v3_decode_mean_s", v3_decode.mean.into()),
        ("trace_v3_decode_vs_hex", (v3_decode.mean / hex_decode.mean).into()),
        ("trace_v3_rle_bytes_ratio", (v3_rle.len() as f64 / v3_hex.len() as f64).into()),
        // TraceFile v4 binary container vs the v3 JSON text of the same
        // two-step capture: whole-container decode wall-clock ratio and
        // the deterministic payload-size ratio (both gated, lower is
        // better), plus the raw means for the trajectory.
        ("trace_v4_encode_mean_s", v4_encode.mean.into()),
        ("trace_v4_decode_mean_s", v4_decode.mean.into()),
        ("trace_v3_container_decode_mean_s", v3c_decode.mean.into()),
        ("trace_v4_stream_append_mean_s", v4_stream.mean.into()),
        ("trace_v4_decode_vs_v3", (v4_decode.mean / v3c_decode.mean).into()),
        ("trace_v4_bytes_ratio", (v4_bytes.len() as f64 / v3_text.len() as f64).into()),
    ];
    // `agos serve` warm path vs the cold one-shot: the resident-state
    // win the `serve_warm_vs_cold_speedup` gate tracks (higher is
    // better — warm answers skip trace decode, bank build and the
    // simulation itself).
    #[cfg(unix)]
    {
        let serve_cold = find("serve_cold_cosim_request");
        let serve_warm = find("serve_warm_cosim_request");
        pairs.push(("serve_cold_mean_s", serve_cold.mean.into()));
        pairs.push(("serve_warm_mean_s", serve_warm.mean.into()));
        pairs.push(("serve_warm_vs_cold_speedup", (serve_cold.mean / serve_warm.mean).into()));
    }
    // Platform-table warm-vs-cold: the shared sweep cache is what keeps
    // repeated Table 2 builds (and the `platforms` figure that reuses the
    // same combos) cheap inside one report context.
    let t2_cold = find("table2_platforms_cold");
    let t2_warm = find("table2_platforms_warm");
    pairs.push(("table2_cold_mean_s", t2_cold.mean.into()));
    pairs.push(("table2_warm_mean_s", t2_warm.mean.into()));
    pairs.push(("table2_warm_vs_cold_speedup", (t2_cold.mean / t2_warm.mean).into()));
    let j = Json::from_pairs(pairs);
    j.write_file(std::path::Path::new("BENCH_sweep.json")).expect("write BENCH_sweep.json");
    println!(
        "wrote BENCH_sweep.json ({} jobs: {:.2}x vs sequential)",
        jobs,
        seq.mean / par.mean
    );
}
