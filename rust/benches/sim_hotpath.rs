//! Micro-benchmarks of the simulator hot paths — the targets of the
//! performance pass (EXPERIMENTS.md §Perf).

use agos::config::{AcceleratorConfig, Scheme, SimOptions};
use agos::nn::zoo;
use agos::sim::{redistribute, simulate_layer, simulate_network, LayerTask, PeModel};
use agos::sparsity::SparsityModel;
use agos::util::bench::{black_box, Bench};
use agos::util::rng::Pcg32;

fn main() {
    let cfg = AcceleratorConfig::default();
    let opts = SimOptions::default();
    let mut b = Bench::new("sim_hotpath");

    // PE per-output model — called once per (tile, layer, image).
    let pe = PeModel::from_config(&cfg);
    b.case("pe_cycles_per_output_x1000", || {
        let mut acc = 0.0;
        for i in 0..1000 {
            let s = (i % 10) as f64 / 10.0;
            acc += pe.cycles_per_output(black_box(1152.0), black_box(s)).0;
        }
        acc
    });

    // Layer execution — 256 tiles with jitter.
    let task = LayerTask {
        name: "bench".into(),
        m: 128,
        u: 28,
        v: 28,
        crs: 1152.0,
        in_sparsity: Some(0.5),
        out_sparsity: Some(0.5),
        input_elems: 128.0 * 30.0 * 30.0,
        weight_elems: 128.0 * 1152.0,
    };
    b.case("simulate_layer_inoutwr", || {
        let mut rng = Pcg32::new(7);
        simulate_layer(&task, &cfg, &opts, Scheme::InOutWr, &mut rng).cycles
    });

    // WDU event loop on a skewed 256-tile workload.
    let mut rng = Pcg32::new(5);
    let work: Vec<f64> = (0..256).map(|_| 1000.0 * (1.0 + 0.3 * rng.gauss()).max(0.05)).collect();
    b.case("wdu_redistribute_256", || redistribute(black_box(&work), 0.3, 0.05).makespan);

    // Whole-network sweeps (the figure-generation workhorse).
    let model = SparsityModel::synthetic(1);
    let small_opts = SimOptions { batch: 1, ..SimOptions::default() };
    for net in [zoo::resnet18(), zoo::vgg16()] {
        b.case(&format!("simulate_{}_b1", net.name), || {
            simulate_network(&net, &cfg, &small_opts, &model, Scheme::InOutWr).total_cycles()
        });
    }
    let dn = zoo::densenet121();
    b.case("simulate_densenet121_b1", || {
        simulate_network(&dn, &cfg, &small_opts, &model, Scheme::InOutWr).total_cycles()
    });
    b.finish();
}
